"""Configuration system for the SPD framework.

Frozen dataclasses describe models, input shapes, meshes and SPD plans.
Everything is hashable/static so configs can parameterize jit'd functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts feed-forward configuration."""

    n_routed: int                 # number of routed experts
    n_shared: int                 # number of always-on shared experts
    top_k: int                    # routed experts per token
    d_ff_expert: int              # hidden dim of each routed/shared expert
    capacity_factor: float = 1.25  # EP dispatch capacity factor
    router_jitter: float = 0.0
    # some models (deepseek) keep the first layer(s) dense
    n_dense_layers: int = 0
    d_ff_dense: int = 0           # d_ff of the dense layers (0 -> use model d_ff)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank queries (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    # hybrid archs attach SSM heads in parallel with attention heads
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. `family` selects the block type."""

    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 => d_model // n_heads

    # attention options
    attn_backend: str = "xla"     # xla | pallas (flash kernel; interpret on CPU)
    kv_dtype: str = "model"       # "model" (= compute dtype) | "int8"
    weight_dtype: str = "model"   # "model" | "int8" (serve-path weight-only
                                  # quant; per-output-column scales)
    qk_norm: bool = False
    qkv_bias: bool = False
    o_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0    # stablelm: partial rotary
    attn_window: int = 0          # 0 => full causal; >0 sliding window
    global_attn_layers: Tuple[int, ...] = ()  # layers that ignore attn_window

    # mlp options
    mlp_bias: bool = False
    gated_mlp: bool = True        # True: SwiGLU-style; False: plain 2-layer MLP
    act: str = "silu"             # silu | gelu | relu

    # norm / embedding
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 20
    pos_emb: str = "rope"         # rope | learned (OPT)

    # modality frontend stubs (audio/vlm): precomputed embeddings are
    # projected and prepended; see models/frontend notes in DESIGN.md
    frontend_dim: int = 0
    frontend_len: int = 0

    # family-specific
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    frontend: Optional[str] = None  # audio_stub | vision_stub (modality stubs)

    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, (
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")

    # ---------------- derived quantities ----------------

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def spd_applicable(self) -> bool:
        """SPD needs a second sync point (the MLP/MoE combine) to defer the
        attention partial-sum to. Pure-SSM blocks have a single sync point."""
        return not self.attn_free

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without a dense KV cache?"""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.attn_window > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            per_layer = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                + d_in * d + d_in  # out proj + norm-ish
            )
        else:
            if self.mla is not None:
                m = self.mla
                q_dim = self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * q_dim if m.q_lora_rank == 0 else (
                    d * m.q_lora_rank + m.q_lora_rank * q_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                kvd = self.n_kv_heads * self.d_head
                qd = self.n_heads * self.d_head
                per_layer += d * (qd + 2 * kvd) + qd * d
            if self.family == "hybrid" and self.ssm is not None:
                s = self.ssm
                d_in = self.n_heads * self.d_head
                per_layer += d * (d_in + 2 * s.n_groups * s.d_state
                                  + d_in // s.head_dim)
                per_layer += s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
            # MLP / MoE
            if self.moe is not None:
                mo = self.moe
                dense_layers = mo.n_dense_layers
                moe_layers = L - dense_layers
                d_ff_dense = mo.d_ff_dense or self.d_ff
                expert = 3 * d * mo.d_ff_expert if self.gated_mlp else 2 * d * mo.d_ff_expert
                per_moe = (mo.n_routed + mo.n_shared) * expert + d * mo.n_routed
                per_dense = (3 if self.gated_mlp else 2) * d * d_ff_dense
                return emb + L * per_layer + moe_layers * per_moe + dense_layers * per_dense
            else:
                per_layer += (3 if self.gated_mlp else 2) * d * self.d_ff
        return emb + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        expert = (3 if self.gated_mlp else 2) * self.d_model * mo.d_ff_expert
        moe_layers = self.n_layers - mo.n_dense_layers
        inactive = moe_layers * (mo.n_routed - mo.top_k) * expert
        return full - inactive


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """A (seq_len, global_batch, kind) workload cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# reduced shapes for smoke tests
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 4, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 128, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 128, 4, "decode"),
    "long_500k": ShapeConfig("long_500k", 512, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / parallelism
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def tp(self) -> int:
        return self.shape[self.axes.index("model")] if "model" in self.axes else 1

    @property
    def dp(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("data", "pod"):
                n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


# Quantization levels a kept sync point (or the logits all-gather) may run
# at.  "drop" is not a level — dropping is the SPD plan's job (drop_mask);
# the comm policy decides how much precision the syncs that REMAIN get.
SYNC_LEVELS = ("exact", "quant8", "quant4")

# user-facing per-block modes accepted by SPDPlanConfig.from_modes /
# LLM.load(comm=...): the cross product of {keep, drop} x SYNC_LEVELS
BLOCK_MODES = ("exact", "quant8", "quant4",
               "drop", "drop+quant8", "drop+quant4")


@dataclass(frozen=True)
class CommPolicy:
    """Per-block communication policy over the sync points SPD keeps.

    SPD's binary plan decides WHICH attention-output syncs disappear;
    `CommPolicy` decides how much precision every surviving collective
    gets: `block_modes[i]` is the quantization level ("exact" | "quant8"
    | "quant4") of block i's kept sync points (the MLP/MoE output
    all-reduce, and the attention-output all-reduce when the block is
    not dropped), and `logits_mode` the level of the final logits
    all-gather.  Orthogonal to the drop mask by construction, so the two
    compose: a block can be dropped AND have its one remaining sync run
    int8 (cf. Flash Communication, arXiv:2412.04964; partial-sync TP,
    arXiv:2506.19645).
    """

    block_modes: Tuple[str, ...]
    logits_mode: str = "exact"

    def __post_init__(self):
        for m in self.block_modes:
            if m not in SYNC_LEVELS:
                raise ValueError(f"bad sync level {m!r} "
                                 f"(expected one of {SYNC_LEVELS})")
        if self.logits_mode not in SYNC_LEVELS:
            raise ValueError(f"bad logits_mode {self.logits_mode!r} "
                             f"(expected one of {SYNC_LEVELS})")

    @property
    def n_quantized(self) -> int:
        return sum(m != "exact" for m in self.block_modes)

    @staticmethod
    def exact(n_layers: int) -> "CommPolicy":
        return CommPolicy(tuple(["exact"] * n_layers))

    @staticmethod
    def uniform(n_layers: int, mode: str,
                logits: str = "exact") -> "CommPolicy":
        return CommPolicy(tuple([mode] * n_layers), logits_mode=logits)


@dataclass(frozen=True)
class SPDPlanConfig:
    """Which blocks drop their attention-output sync point.

    `drop_mask` is a tuple of per-layer booleans (True = SPD block).
    `comm` (optional) attaches a per-block CommPolicy for the syncs the
    plan keeps; None means every kept sync and the logits all-gather run
    exact (the paper's setting).
    """

    drop_mask: Tuple[bool, ...]
    comm: Optional[CommPolicy] = None

    def __post_init__(self):
        if (self.comm is not None
                and len(self.comm.block_modes) != len(self.drop_mask)):
            raise ValueError(
                f"comm policy covers {len(self.comm.block_modes)} blocks, "
                f"plan has {len(self.drop_mask)}")

    @property
    def n_dropped(self) -> int:
        return sum(self.drop_mask)

    @property
    def fraction(self) -> float:
        return self.n_dropped / max(len(self.drop_mask), 1)

    # ---------------- comm-policy view ----------------

    @property
    def qmodes(self) -> Optional[Tuple[str, ...]]:
        """Per-layer kept-sync levels, or None for all-exact (the extra
        segmentation key consumed by layer_kinds.plan_segments)."""
        return None if self.comm is None else self.comm.block_modes

    @property
    def logits_mode(self) -> str:
        return "exact" if self.comm is None else self.comm.logits_mode

    def block_mode(self, i: int) -> Optional[str]:
        """Kept-sync level of block i; None defers to the trace-time
        sync_compression context (collectives.py)."""
        return None if self.comm is None else self.comm.block_modes[i]

    def with_comm(self, comm: Optional[CommPolicy]) -> "SPDPlanConfig":
        return SPDPlanConfig(self.drop_mask, comm)

    @staticmethod
    def from_modes(modes, logits: str = "exact") -> "SPDPlanConfig":
        """Build a plan+policy from user-facing per-block modes
        (BLOCK_MODES): "drop[+quantN]" drops the attention sync and runs
        the surviving MLP sync at the given level; plain levels keep both
        syncs at that level."""
        drop, levels = [], []
        for m in modes:
            if m not in BLOCK_MODES:
                raise ValueError(f"bad block mode {m!r} "
                                 f"(expected one of {BLOCK_MODES})")
            if m.startswith("drop"):
                drop.append(True)
                levels.append(m.split("+", 1)[1] if "+" in m else "exact")
            else:
                drop.append(False)
                levels.append(m)
        return SPDPlanConfig(tuple(drop),
                             CommPolicy(tuple(levels), logits_mode=logits))

    def modes(self):
        """Inverse of from_modes: the user-facing per-block mode list."""
        out = []
        for d, m in zip(self.drop_mask,
                        self.qmodes or ("exact",) * len(self.drop_mask)):
            if d:
                out.append("drop" if m == "exact" else f"drop+{m}")
            else:
                out.append(m)
        return out

    @staticmethod
    def none(n_layers: int) -> "SPDPlanConfig":
        return SPDPlanConfig(tuple([False] * n_layers))

    @staticmethod
    def full(n_layers: int) -> "SPDPlanConfig":
        return SPDPlanConfig(tuple([True] * n_layers))

    @staticmethod
    def first_k(n_layers: int, k: int) -> "SPDPlanConfig":
        return SPDPlanConfig(tuple([i < k for i in range(n_layers)]))

    @staticmethod
    def from_ranking(ranking, n_spd: int, n_layers: int) -> "SPDPlanConfig":
        drop = [False] * n_layers
        for idx in list(ranking)[:n_spd]:
            drop[int(idx)] = True
        return SPDPlanConfig(tuple(drop))

    def segments(self):
        """Contiguous runs of equal drop-flag: [(start, length, dropped)].

        The model stacks per-segment params so lax.scan keeps the HLO small
        even for heterogeneous plans."""
        segs = []
        if not self.drop_mask:
            return segs
        start, cur = 0, self.drop_mask[0]
        for i, flag in enumerate(self.drop_mask[1:], 1):
            if flag != cur:
                segs.append((start, i - start, cur))
                start, cur = i, flag
        segs.append((start, len(self.drop_mask) - start, cur))
        return segs


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
