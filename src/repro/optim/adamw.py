"""AdamW from scratch (pytree-based), with fp32 moments and optional
fp32 master weights for low-precision params."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, *, master: bool = True):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
    }
    if master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    """Returns (new_params, new_state). lr may be a scalar array."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        new = master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                             + weight_decay * master)
        return new, m, v

    masters = state.get("master", jax.tree.map(
        lambda p: p.astype(jnp.float32), params))
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(masters)
    new_w, new_m, new_v = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        nw, nm, nv = upd(g, m, v, w)
        new_w.append(nw)
        new_m.append(nm)
        new_v.append(nv)
    new_master = jax.tree.unflatten(treedef, new_w)
    new_state = {"step": step,
                 "m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v)}
    if "master" in state:
        new_state["master"] = new_master
    new_params = jax.tree.map(
        lambda w, p: w.astype(p.dtype), new_master, params)
    return new_params, new_state


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(grads, max_norm, *, precomputed_norm=None):
    n = precomputed_norm if precomputed_norm is not None else global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), n
