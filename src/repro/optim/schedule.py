"""LR schedules: linear warmup + {cosine, linear, constant} decay."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, *, base_lr: float, warmup: int = 0,
                  total: int = 1, final_frac: float = 0.1):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        w = jnp.maximum(warmup, 1)
        warm = base_lr * jnp.minimum(s / w, 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        if kind == "cosine":
            dec = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        elif kind == "linear":
            dec = 1.0 - (1.0 - final_frac) * prog
        else:
            dec = 1.0
        return jnp.where(s < warmup, warm, base_lr * dec)
    return sched
