from repro.optim.adamw import (adamw_init, adamw_update, apply_updates,
                               global_norm, clip_by_global_norm)
from repro.optim.schedule import make_schedule

__all__ = ["adamw_init", "adamw_update", "apply_updates", "global_norm",
           "clip_by_global_norm", "make_schedule"]
