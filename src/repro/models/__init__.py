"""Pure-JAX model zoo shared by both execution engines."""
