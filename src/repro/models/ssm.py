"""Mamba2 SSD (state-space duality) — shard-local math.

Chunked quadratic-dual form (arXiv:2405.21060): within a chunk the output
is an attention-like masked contraction; across chunks a small recurrent
state (H, P, N) is carried by a scan.  This file is the pure-jnp oracle;
kernels/ssd_scan.py is the Pallas TPU version of the same contraction.

Shapes (shard-local):
  x  (B, S, H, P)   per-head inputs          H = local heads, P = head_dim
  dt (B, S, H)      softplus-activated step sizes
  A  (H,)           negative decay rates
  Bm (B, S, G, N)   input projections        G = groups (shared across heads)
  Cm (B, S, G, N)   output projections
  D  (H,)           skip connection
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segsum(a):
    """log-decay segment sums: a (..., Q) -> L (..., Q, Q) with
    L[i,j] = sum_{k=j+1..i} a[k] for i>=j, -inf otherwise."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def _group_expand(m, h):
    """(B,S,G,N) -> (B,S,H,N) by repeating each group over its heads."""
    g = m.shape[2]
    rep = h // g
    return jnp.repeat(m, rep, axis=2)


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int, initial_state=None):
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 internally."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xd = x.astype(f32)
    dt = dt.astype(f32)
    Bh = _group_expand(Bm.astype(f32), h)     # (B,S,H,N)
    Ch = _group_expand(Cm.astype(f32), h)
    dA = dt * A.astype(f32)                   # (B,S,H) log-decay per step

    # chunk views: (nc, B, Q, ...)
    def chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc, dAc = map(chunks, (xd, dt, Bh, Ch, dA))

    def body(state, inp):
        xq, dtq, bq, cq, daq = inp            # (B,Q,H,...)
        csum = jnp.cumsum(daq, axis=1)        # (B,Q,H)
        # ---- intra-chunk (quadratic dual form) ----
        L = jnp.exp(segsum(daq.transpose(0, 2, 1)))          # (B,H,Q,Q)
        scores = jnp.einsum("bqhn,bkhn->bhqk", cq, bq) * L   # (B,H,Q,Q)
        y_intra = jnp.einsum("bhqk,bkh,bkhp->bqhp", scores, dtq, xq)
        # ---- inter-chunk: contribution of carried state ----
        decay_in = jnp.exp(csum)                             # (B,Q,H)
        y_inter = jnp.einsum("bqhn,bhpn,bqh->bqhp", cq, state, decay_in)
        # ---- state update ----
        total = csum[:, -1]                                  # (B,H)
        decay_out = jnp.exp(total[:, None] - csum)           # (B,Q,H)
        upd = jnp.einsum("bqh,bqh,bqhp,bqhn->bhpn", decay_out, dtq, xq, bq)
        state = jnp.exp(total)[..., None, None] * state + upd
        return state, y_intra + y_inter

    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), f32)
    state, yc = jax.lax.scan(body, initial_state.astype(f32), (xc, dtc, Bc, Cc, dAc))
    y = yc.swapaxes(0, 1).reshape(b, s, h, p)
    y = y + xd * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_decode_step(x, dt, A, Bm, Cm, D, state):
    """One-token recurrence. x (B,1,H,P), state (B,H,P,N) ->
    (y (B,1,H,P), new_state)."""
    b, _, h, p = x.shape
    f32 = jnp.float32
    xd = x[:, 0].astype(f32)                  # (B,H,P)
    dt0 = dt[:, 0].astype(f32)                # (B,H)
    Bh = _group_expand(Bm.astype(f32), h)[:, 0]   # (B,H,N)
    Ch = _group_expand(Cm.astype(f32), h)[:, 0]
    decay = jnp.exp(dt0 * A.astype(f32))      # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt0, xd, Bh)
    state = decay[..., None, None] * state.astype(f32) + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xd * D.astype(f32)[None, :, None]
    return y[:, None].astype(x.dtype), state


def ssd_reference(x, dt, A, Bm, Cm, D, initial_state=None):
    """O(S) sequential oracle (used only in tests to validate the chunked
    form and the Pallas kernel)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Bh = _group_expand(Bm.astype(jnp.float32), h)
    Ch = _group_expand(Cm.astype(jnp.float32), h)
    state = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            x[:, t:t + 1], dt[:, t:t + 1], A, Bm[:, t:t + 1], Cm[:, t:t + 1],
            D, state)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), state


def causal_conv(x, w, state=None):
    """Depthwise causal conv.  x (B,S,C), w (K,C).  If `state` (B,K-1,C) is
    given, runs in streaming mode and returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : k - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)           # (B, S+K-1, C)
    idx = jnp.arange(x.shape[1])[:, None] + jnp.arange(k)[None, :]
    win = xp[:, idx]                                  # (B,S,K,C)
    y = jnp.einsum("bskc,kc->bsc", win.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state
