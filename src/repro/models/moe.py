"""Mixture-of-experts FFN — shard-local math with EP on the TP axis.

Experts are sharded over the "model" mesh axis (EP degree == TP degree).
Activations entering the block are replicated over that axis, so there is
no all-to-all: every shard routes all tokens, runs its LOCAL experts on
the tokens routed to them (capacity-bounded gather dispatch), and the
weighted combine rides the block's single output all-reduce — which is
exactly the sync point SPD's deferred attention residual is added to.

Shard-local expert weights: wg/wu (E_l, d, ff), wd (E_l, ff, d) where
E_l = padded_experts / tp (zero-padded experts route nothing: the router
logit rows for padding experts are -inf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn


def route(h, w_router, top_k: int, n_routed: int):
    """h (T,d) fp32 router input; w_router (d, E_pad).

    Returns gates (T,k), expert ids (T,k) in PADDED global numbering, plus
    the aux load-balance loss.  Padding experts (col >= n_routed) are
    masked to -inf so they never win top-k."""
    logits = h.astype(jnp.float32) @ w_router.astype(jnp.float32)  # (T,E)
    e_pad = logits.shape[-1]
    if e_pad > n_routed:
        pad_mask = jnp.arange(e_pad) >= n_routed
        logits = jnp.where(pad_mask[None], -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                       # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # switch-style aux loss: E * sum_e f_e * P_e  (over real experts)
    t = h.shape[0]
    onehot = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)         # (T,k,E)
    f_e = onehot.sum((0, 1)) / (t * top_k)
    p_e = probs.mean(0)
    aux = n_routed * jnp.sum(f_e * p_e)
    return gates, idx, aux


def dispatch_local(idx, gates, e_lo, e_l: int, capacity: int):
    """Build gather/scatter plans for this shard's experts [e_lo, e_lo+e_l).

    `e_lo` may be a traced shard offset (axis_index * e_l); `e_l` and
    `capacity` are static.  idx/gates (T,k).  Returns:
      slot_token (E_l, C) int32   token index feeding each expert slot
                                  (T = padding row -> zero input),
      tok_slot   (T, k)  int32    flat slot (e_l*C + c) for each assignment
                                  or -1 if not local / over capacity,
    """
    t, k = idx.shape
    local = (idx >= e_lo) & (idx < e_lo + e_l)              # (T,k)
    lid = jnp.where(local, idx - e_lo, 0)                   # (T,k)
    # position of each assignment within its expert's queue (row-major order)
    onehot = jnp.where(local[..., None],
                       jax.nn.one_hot(lid, e_l, dtype=jnp.int32), 0)  # (T,k,E_l)
    flat = onehot.reshape(t * k, e_l)
    pos = jnp.cumsum(flat, axis=0) - flat                   # (T*k, E_l)
    pos = (pos * flat).sum(-1).reshape(t, k)                # (T,k)
    ok = local & (pos < capacity)
    # scatter token ids into slots
    slot = jnp.where(ok, lid * capacity + pos, e_l * capacity)  # overflow bin
    slot_token = jnp.full((e_l * capacity + 1,), t, jnp.int32)
    slot_token = slot_token.at[slot.reshape(-1)].set(
        jnp.repeat(jnp.arange(t, dtype=jnp.int32), k), mode="drop")
    slot_token = slot_token[:-1].reshape(e_l, capacity)
    tok_slot = jnp.where(ok, lid * capacity + pos, -1)
    return slot_token, tok_slot


def expert_ffn(xe, wg, wu, wd, act: str, gated: bool):
    """xe (E_l, C, d); batched expert MLP -> (E_l, C, d)."""
    a = act_fn(act)
    up = jnp.einsum("ecd,edf->ecf", xe, wu)
    if gated:
        gate = jnp.einsum("ecd,edf->ecf", xe, wg)
        hidden = a(gate) * up
    else:
        hidden = a(up)
    return jnp.einsum("ecf,efd->ecd", hidden, wd)


def moe_local(h, gates, tok_slot, slot_token, wg, wu, wd, act: str,
              gated: bool):
    """Run local experts and combine back to token order.

    h (T,d); returns partial (T,d) = Σ_local-assignments gate * expert_out.
    """
    t, d = h.shape
    e_l, cap = slot_token.shape
    hp = jnp.concatenate([h, jnp.zeros((1, d), h.dtype)], 0)  # padding row
    xe = hp[slot_token.reshape(-1)].reshape(e_l, cap, d)
    ye = expert_ffn(xe, wg, wu, wd, act, gated)               # (E_l,C,d)
    ye_flat = jnp.concatenate(
        [ye.reshape(e_l * cap, d), jnp.zeros((1, d), ye.dtype)], 0)
    picked = ye_flat[tok_slot]                                # (T,k,d) (-1 -> pad row)
    picked = jnp.where((tok_slot >= 0)[..., None], picked, 0.0)
    return jnp.einsum("tk,tkd->td", gates.astype(picked.dtype), picked)
