"""Shared model components: norms, activations, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x, w, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def layernorm(x, w, b, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w + b


def norm_apply(x, p, cfg):
    """Dispatch on cfg.norm; p is {"w": ...} or {"w":..., "b":...}."""
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"], cfg.norm_eps)
    return rmsnorm(x, p["w"], cfg.norm_eps)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_rot: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float32) / d_rot))


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, H, Dh); positions: (..., S) int32.  Rotates the first
    `fraction` of Dh (stablelm partial rotary), rotate-half convention."""
    dh = x.shape[-1]
    d_rot = int(dh * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    inv = jnp.asarray(rope_freqs(d_rot, theta))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d_rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]   # broadcast over heads
    sin = sin[..., None, :]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers (deterministic per-leaf from a path hash)
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def fold_path(key, path: str):
    # zlib.crc32, not builtin hash(): the latter is randomized per process
    # (PYTHONHASHSEED), which made same-seed runs non-reproducible across
    # invocations.
    import zlib
    h = np.uint32(zlib.crc32(path.encode()) % (2 ** 31))
    return jax.random.fold_in(key, h)
