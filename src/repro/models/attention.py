"""Attention math, shard-local (operates on the heads a device owns).

All functions are pure jnp and engine-agnostic: the TP engines hand them
shard-local head counts.  `attend` is the dense oracle; `attend_chunked`
is the XLA flash-style query-chunked path used for long sequences (and is
the reference the Pallas flash kernel in kernels/ must match).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B,Sq,Hq,Dh), k: (B,Sk,Hkv,Dh) with Hq % Hkv == 0 ->
    scores (B,Hq,Sq,Sk) in fp32."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    return s.reshape(b, hkv * g, sq, k.shape[1])


def _gqa_combine(p, v):
    """p: (B,Hq,Sq,Sk) fp32, v: (B,Sk,Hkv,Dh) -> (B,Sq,Hq,Dh)."""
    b, hq, sq, sk = p.shape
    hkv = v.shape[2]
    g = hq // hkv
    p = p.reshape(b, hkv, g, sq, sk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, v.shape[-1])


def causal_mask(q_pos, kv_pos, window: int = 0):
    """(..., Sq) x (..., Sk) int32 -> bool (..., Sq, Sk); True = attend."""
    m = kv_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def tree_mask(pos, anc, kv_pos):
    """Attention mask for a speculative TREE chunk (docs/speculative.md).

    The chunk's C tokens occupy DISTINCT cache slots pos..pos+C-1
    (scattered by chunk index) but sit at tree positions pos+depth
    (RoPE); visibility follows the tree, not the slot order: kv slot m
    is visible to chunk token i iff it holds committed history
    (m < pos) or an in-chunk ancestor of i (anc[i, m - pos], diagonal
    True).  pos (B,) chunk starts; anc (C, C) bool; kv_pos (B, Sk) slot
    indices.  Returns bool (B, C, Sk); True = attend.
    """
    c = anc.shape[0]
    rel = kv_pos - pos[:, None]                          # (B, Sk)
    in_chunk = (rel >= 0) & (rel < c)
    within = jnp.take(anc, jnp.clip(rel, 0, c - 1), axis=1)   # (C, B, Sk)
    within = jnp.moveaxis(within, 0, 1)                  # (B, C, Sk)
    return (rel < 0)[:, None, :] | (in_chunk[:, None, :] & within)


def attend(q, k, v, mask, scale: float | None = None):
    """Dense softmax attention oracle.

    q (B,Sq,Hq,Dh), k/v (B,Sk,Hkv,Dh), mask bool (B,Sq,Sk) or (B,1,Sq,Sk).
    Returns (B,Sq,Hq,Dh) in q.dtype.
    """
    dh = q.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    s = _gqa_scores(q * scale, k)
    if mask.ndim == 3:
        mask = mask[:, None]
    s = jnp.where(mask, s, NEG_INF)
    # guard fully-masked rows (padding) -> zero output instead of NaN
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-20)
    return _gqa_combine(p, v).astype(q.dtype)


@partial(jax.checkpoint, static_argnums=(5, 6))
def _attend_q_chunk(q, k, v, q_pos, kv_pos, window, scale):
    mask = causal_mask(q_pos, kv_pos, window)
    return attend(q, k, v, mask, scale)


def attend_chunked(q, k, v, q_pos, kv_pos, *, window: int = 0,
                   q_chunk: int = 1024, scale: float | None = None):
    """Query-chunked causal attention: O(q_chunk * Sk) score memory.

    Scans over query chunks; each chunk attends to the full K/V with a
    causal (+optional sliding window) mask built from positions.  This is
    the XLA-level flash pattern; kernels/flash_attention.py is the Pallas
    version of the same contraction.
    """
    b, sq, hq, dh = q.shape
    if sq <= q_chunk:
        return _attend_q_chunk(q, k, v, q_pos, kv_pos, window, scale)
    n = sq // q_chunk
    main = n * q_chunk
    qs = (q[:, :main].reshape(b, n, q_chunk, hq, dh)
          .transpose(1, 0, 2, 3, 4))
    ps = q_pos[:, :main].reshape(b, n, q_chunk).transpose(1, 0, 2)

    def body(_, qc):
        qi, pi = qc
        return None, _attend_q_chunk(qi, k, v, pi, kv_pos, window, scale)

    _, out = jax.lax.scan(body, None, (qs, ps))
    dv = out.shape[-1]             # MLA: v head dim != q head dim
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, main, hq, dv)
    if main < sq:   # ragged tail (e.g. a modality prefix shifts the length)
        tail = _attend_q_chunk(q[:, main:], k, v, q_pos[:, main:], kv_pos,
                               window, scale)
        out = jnp.concatenate([out, tail], axis=1)
    return out


def attention_any(q, k, v, q_pos, kv_pos, *, window: int = 0,
                  q_chunk: int = 1024, scale: float | None = None):
    """Dispatch: dense for short q, chunked for long."""
    if q.shape[1] > q_chunk:
        return attend_chunked(q, k, v, q_pos, kv_pos, window=window,
                              q_chunk=q_chunk, scale=scale)
    mask = causal_mask(q_pos, kv_pos, window)
    return attend(q, k, v, mask, scale)


# ---------------------------------------------------------------------------
# Decode-from-cache helpers
# ---------------------------------------------------------------------------

def paged_attend(q, k_pool, v_pool, page_table, pos, *,
                 scale: float | None = None, anc=None):
    """Paged-KV attention, XLA path: gather ONLY the table's pages.

    q (B,C,Hq,Dh) at absolute positions pos[b]..pos[b]+C-1; k_pool/v_pool
    (P+1, ps, Hkv, Dh) are the shared physical page pools (page P is the
    trash page); page_table (B,n) int32, -1 = unallocated (reads trash,
    fully masked).  Reuses `attend`, so numerics are bit-identical to the
    dense decode path: masked lanes contribute exactly 0.0, and
    power-of-two table widths (runtime bucketing) keep XLA's balanced
    reduction trees associating the valid prefix identically.  The fused
    Pallas kernel (kernels/ops.paged_attention) is the TPU path that
    skips even this bucketed gather.

    `anc` (C, C) bool switches the chunk to TREE visibility (tree_mask):
    the C slots at pos..pos+C-1 attend per the ancestor matrix instead
    of slot order (speculative tree verification)."""
    b, c = q.shape[:2]
    pn1, ps, hkv, dh = k_pool.shape
    n = page_table.shape[1]
    pt = jnp.where(page_table < 0, pn1 - 1, page_table)
    kg = jnp.take(k_pool, pt.reshape(-1), axis=0).reshape(b, n * ps, hkv, dh)
    vg = jnp.take(v_pool, pt.reshape(-1), axis=0).reshape(b, n * ps, hkv, dh)
    kv_pos = jnp.broadcast_to(jnp.arange(n * ps)[None], (b, n * ps))
    if anc is None:
        q_pos = pos[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
        mask = causal_mask(q_pos, kv_pos)
    else:
        mask = tree_mask(pos, anc, kv_pos)
    mask &= (jnp.repeat(page_table, ps, axis=1) >= 0)[:, None, :]
    return attend(q, kg, vg, mask, scale)


def decode_attend(q, k_cache, v_cache, pos, *, window: int = 0,
                  scale: float | None = None):
    """Single-token decode: q (B,1,Hq,Dh); caches (B,S,Hkv,Dh);
    pos (B,) current absolute position.  For windowed layers the cache is a
    rolling buffer of size S=window (slot = p % window); validity masking
    only needs how many slots are filled, since RoPE was applied pre-cache.
    """
    b, s = k_cache.shape[0], k_cache.shape[1]
    slots = jnp.arange(s)[None, :]                      # (1,S)
    if window > 0:
        filled = jnp.minimum(pos[:, None] + 1, s)       # (B,1)
        valid = slots < filled
    else:
        valid = slots <= pos[:, None]
    mask = valid[:, None, :]                            # (B,1(Sq),S)
    return attend(q, k_cache, v_cache, mask, scale)


def cache_update(k_cache, v_cache, k_new, v_new, pos, *, window: int = 0):
    """Write one token's k/v at pos (rolling for windowed layers)."""
    slot = pos % window if window > 0 else pos          # (B,)
    b = k_cache.shape[0]
    bi = jnp.arange(b)
    k_cache = k_cache.at[bi, slot].set(k_new[:, 0])
    v_cache = v_cache.at[bi, slot].set(v_new[:, 0])
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Int8 KV cache (beyond-paper: decode at 32k context is HBM-bound on the
# cache read; per-(pos, head) absmax scales halve the cache bytes at
# <0.5% attention-output error — tests/test_kv_int8.py)
# ---------------------------------------------------------------------------

def kv_quantize(x):
    """x (..., Dh) -> (int8 (..., Dh), scale (...,) bf16)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), -1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)
