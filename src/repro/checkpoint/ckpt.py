"""Atomic, integrity-checked, resumable checkpoints.

Layout:  <dir>/step_00001234/
             manifest.json       {step, meta, leaves: {key: {shape, dtype,
                                  crc32, file}}}
             <leaf files>.npy

Write protocol: serialize into ``<dir>/.tmp_step_N`` then ``os.replace`` to
the final name — a crash mid-write never produces a directory that parses
as a checkpoint.  Load protocol: newest step whose manifest exists AND
whose every leaf passes a crc32 check; corrupt/partial checkpoints are
skipped (fault-tolerance tests exercise this by truncating files).

Arrays are gathered to host (this is a single-process runtime; the
multi-host production variant would write per-host shard files keyed by
process index — same manifest schema, noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten_into(tree_like, leaves: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    vals = []
    for path, proto in flat:
        key = jax.tree_util.keystr(path)
        arr = leaves[key]
        assert tuple(arr.shape) == tuple(proto.shape), (key, arr.shape,
                                                        proto.shape)
        vals.append(arr.astype(proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, [v for _, v in
                                                  zip(flat, vals)])


def save_checkpoint(directory: str, step: int, tree, meta: Optional[dict] = None):
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:010d}"
    tmp = os.path.join(directory, f".tmp_{name}")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for i, (key, arr) in enumerate(leaves.items()):
        fname = f"leaf_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "crc32": crc, "file": fname}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _validate(path: str) -> Optional[dict]:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.isfile(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for key, rec in manifest["leaves"].items():
            fpath = os.path.join(path, rec["file"])
            with open(fpath, "rb") as fh:
                if zlib.crc32(fh.read()) != rec["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def list_checkpoints(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in sorted(os.listdir(directory)):
        if name.startswith("step_"):
            out.append(os.path.join(directory, name))
    return out


def load_checkpoint(directory: str, tree_like=None,
                    step: Optional[int] = None) -> Optional[Tuple[int, Any, dict]]:
    """Newest VALID checkpoint (or exact step).  Returns (step, tree, meta)
    with `tree` structured like `tree_like` (or a flat {key: array} dict)."""
    cands = list_checkpoints(directory)
    if step is not None:
        cands = [c for c in cands if c.endswith(f"step_{step:010d}")]
    for path in reversed(cands):
        manifest = _validate(path)
        if manifest is None:
            continue
        leaves = {}
        for key, rec in manifest["leaves"].items():
            leaves[key] = np.load(os.path.join(path, rec["file"]))
        if tree_like is not None:
            tree = _unflatten_into(tree_like, leaves)
        else:
            tree = leaves
        return manifest["step"], tree, manifest.get("meta", {})
    return None


class CheckpointManager:
    """Cadenced saves + rotation + resume."""

    def __init__(self, directory: str, *, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree, meta: Optional[dict] = None,
                   force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return None
        path = save_checkpoint(self.directory, step, tree, meta)
        self._rotate()
        return path

    def _rotate(self):
        cands = [c for c in list_checkpoints(self.directory)]
        for old in cands[: max(0, len(cands) - self.keep)]:
            shutil.rmtree(old, ignore_errors=True)

    def restore(self, tree_like=None):
        return load_checkpoint(self.directory, tree_like)
