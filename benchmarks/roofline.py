"""§Roofline: the three terms per (arch × shape × mesh) cell.

  compute term    = step_FLOPs / (chips × peak_FLOP/s)
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = Σ_axis ring_time(ledger payload, group, link bw)

Sources (see benchmarks/analytic.py header for WHY the first two are
analytic): step_FLOPs and HBM bytes from first-principles models of the
exact lowered code; collective payloads from the dry-run's scan-aware
trace ledger; the dry-run JSON's compiled cost_analysis()/memory_analysis
values are shown as the HLO cross-check (they undercount while-loop
bodies, recorded as-is).

Output: per-cell terms, dominant bottleneck, MODEL/step-FLOP ratio, and
the roofline fraction = compute_term / max(all terms) — i.e. how close
the step is to being compute-bound at peak.
"""
import argparse
import glob
import json
import os

HW = {
    "peak_flops_bf16": 197e12,
    "hbm_gbps": 819e9,
    "ici_bw": 50e9,
    "dcn_bw": 1.5e9,
}


def ring_time(payload, n, bw, op="all-reduce"):
    """Ring-collective wall time from the op's INPUT payload bytes.

    all-reduce:       2 (n-1)/n · p / bw
    reduce-scatter:     (n-1)/n · p / bw      (p = full input)
    all-gather:         (n-1)   · p / bw      (p = local slice; output n·p)
    collective-permute:           p / bw
    """
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * payload / bw
    if op == "reduce-scatter":
        return (n - 1) / n * payload / bw
    if op == "all-gather":
        return (n - 1) * payload / bw
    return payload / bw


def collective_term(rec):
    """Ledger payloads (per-device bytes) -> seconds, per mesh axis."""
    tp = rec["tp"]
    n = rec["n_devices"]
    multi = rec["mesh"] == "multi"
    dp_ici = n // tp // (2 if multi else 1)
    t = 0.0
    detail = {}
    for key, payload in rec["ledger_bytes_per_device"].items():
        op, axis = key.split("@")
        if axis == "model":
            tt = ring_time(payload, tp, HW["ici_bw"], op)
        elif axis == "data":
            tt = ring_time(payload, dp_ici, HW["ici_bw"], op)
        elif axis == "pod":
            tt = ring_time(payload, 2, HW["dcn_bw"], op)
        else:  # "pod+data" composite: ICI stage + DCN stage
            tt = (ring_time(payload, dp_ici, HW["ici_bw"], op)
                  + ring_time(payload, 2, HW["dcn_bw"], op))
        t += tt
        detail[key] = tt
    return t, detail


def analyze(rec):
    if not rec.get("applicable", True):
        return None
    from repro.config.base import SHAPES
    from repro.configs import get_config
    from benchmarks.analytic import (hbm_bytes_per_device,
                                     model_flops_global, step_flops_global)

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    tp = rec["tp"]
    dp = chips // tp
    mb = max(1, shape.global_batch // dp) if shape.kind == "train" else 1

    flops = step_flops_global(cfg, shape)
    t_comp = flops / (chips * HW["peak_flops_bf16"])
    # beyond-paper variants change the byte model, not the flop model
    pb = 1.06 if rec.get("w_int8") else 2.0     # int8 + per-col scales
    kb = 1.12 if rec.get("kv_int8") else 2.0    # int8 + per-(pos,head) scale
    mem = hbm_bytes_per_device(cfg, shape, chips=chips, tp=tp,
                               microbatches=mb, param_bytes=pb,
                               kv_bytes=kb)
    t_mem = mem.total / HW["hbm_gbps"]
    t_coll, detail = collective_term(rec)
    dom = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    mf = model_flops_global(cfg, shape)
    step_time = max(t_comp, t_mem, t_coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "spd": rec["spd"], "sync_q8": rec.get("sync_q8", False),
        "kv_int8": rec.get("kv_int8", False),
        "w_int8": rec.get("w_int8", False),
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "dominant": dom[1],
        "step_time_est": step_time,
        "roofline_frac": t_comp / step_time if step_time > 0 else 0.0,
        "model_flops": mf, "step_flops": flops,
        "useful_ratio": mf / flops,
        "hlo_flops_crosscheck": rec["flops_total"],
        "mem_model": {"params_local": mem.params_local,
                      "cache_local": mem.cache_local,
                      "act": mem.act_traffic, "opt": mem.opt_traffic,
                      "total": mem.total},
        "mem_hlo_crosscheck": rec["mem_per_device"],
        "hlo_collectives": rec["hlo_collective_op_counts"],
        "coll_detail": detail,
        "tokens_or_batch": rec["tokens"],
        "kind": rec["kind"],
    }


def load_cells(dr_dir):
    out = []
    for p in sorted(glob.glob(os.path.join(dr_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def table(rows, spd=None, mesh="single"):
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "dominant | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None or r["mesh"] != mesh:
            continue
        if spd is not None and abs(r["spd"] - spd) > 1e-9:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['t_compute']*1e3:.3f} | {r['t_memory']*1e3:.3f} "
            f"| {r['t_collective']*1e3:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |")
    return "\n".join(out)


def run(csv, dr_dir="results/dryrun2"):
    rows = [analyze(c) for c in load_cells(dr_dir)]
    for r in rows:
        if r is None:
            continue
        csv(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/"
            f"spd{int(r['spd']*100)}",
            r["step_time_est"] * 1e6,
            f"dom={r['dominant']} comp={r['t_compute']*1e3:.3f}ms "
            f"mem={r['t_memory']*1e3:.3f}ms "
            f"coll={r['t_collective']*1e3:.3f}ms "
            f"frac={r['roofline_frac']:.2f}")
    return [r for r in rows if r is not None]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dr-dir", default="results/dryrun2")
    ap.add_argument("--md")
    ap.add_argument("--json")
    args = ap.parse_args()
    rows = [analyze(c) for c in load_cells(args.dr_dir)]
    md = []
    for mesh in ("single", "multi"):
        for spd in (0.0, 0.7):
            md.append(f"\n### mesh={mesh}, SPD={int(spd*100)}%\n")
            md.append(table(rows, spd=spd, mesh=mesh))
    text = "\n".join(md)
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r for r in rows if r], f, indent=1)


if __name__ == "__main__":
    main()
