"""Fig 7/8 analog: quality vs SPD budget for ZS / ZS+B2B / ZS+B2B+HG.

Reduced scale (CPU container): perplexity + induction-cloze accuracy on
the synthetic suites, SPD budgets {25, 50, 75, 100}% of blocks, ranked by
measured sensitivity (Algorithm 1).  The paper's qualitative claims under
test: ZS holds quality in the in-sensitive region then degrades; B2B
recovers SBs; HG+B2B adds recovery on top for ESBs."""
import numpy as np

from benchmarks._common import Timer, emit_json, quality, train_reduced
from repro.config.base import SPDPlanConfig
from repro.core import model as M
from repro.core import sensitivity as S
from repro.core import simtp, spd as SPD
from repro.data.synthetic import calibration_batches, cloze_suite


def run(csv):
    cfg, canonical = train_reduced("smollm-360m", steps=400, seq=64)
    tp = 2
    calib = calibration_batches(cfg.vocab_size, 16, 64, batch=8)[:2]
    suite = cloze_suite(cfg.vocab_size, 128, 64)
    plan0 = SPDPlanConfig.none(cfg.n_layers)
    ppl0, acc0 = quality(cfg, canonical, plan0, tp, calib, suite)
    csv("accuracy/baseline", 0.0, f"ppl={ppl0:.3f} cloze={acc0:.3f}")

    split0 = simtp.prepare_params(canonical, cfg, plan0, tp)
    sens = S.measure_sensitivity(cfg, split0, calib, tp, q_chunk=64)

    rows = [{"budget": 0.0, "strategy": "TP", "ppl": ppl0, "acc": acc0}]
    for budget in (0.25, 0.5, 0.75, 1.0):
        n_spd = int(round(cfg.n_layers * budget))
        plan = S.plan_from_ranking(sens, n_spd, cfg.n_layers)

        t = Timer()
        ppl_zs, acc_zs = quality(cfg, canonical, plan, tp, calib, suite)
        csv(f"accuracy/zs@{int(budget*100)}", t.us(),
            f"ppl={ppl_zs:.3f} cloze={acc_zs:.3f}")
        rows.append({"budget": budget, "strategy": "ZS", "ppl": ppl_zs,
                     "acc": acc_zs})

        for strat, taus in (("ZS+B2B", (-1e18, 1e18)),
                            ("ZS+B2B+HG", (-1e18, -1e17))):
            # tau1=-inf -> every chosen block at least distills;
            # tau2 below min sensitivity -> every chosen block is ESB
            tau1, tau2 = taus
            t = Timer()
            padded, plan2, rep = SPD.apply_spd(
                cfg, canonical, calib, tp, n_spd=n_spd, tau1=tau1,
                tau2=tau2, lr=5e-4, epochs=3, q_chunk=64)
            ppl_r, acc_r = quality(cfg, padded, plan2, tp, calib, suite,
                                   already_padded=True)
            csv(f"accuracy/{strat.lower()}@{int(budget*100)}", t.us(),
                f"ppl={ppl_r:.3f} cloze={acc_r:.3f} "
                f"distilled={len(rep.distill_losses)} "
                f"grouped={len(rep.grouping)}")
            rows.append({"budget": budget, "strategy": strat, "ppl": ppl_r,
                         "acc": acc_r})
    emit_json("accuracy", {"arch": cfg.name, "steps": 400, "tp": tp},
              rows)
    return rows
