"""Cluster serving: DP-over-TP throughput scaling at 1/2/4 replicas.

Drives a skewed bursty arrival trace — Zipf prompt popularity over a
small set of shared prefixes (the realistic "few hot system prompts"
shape) with Poisson-burst arrivals — through `repro.cluster`'s router
at 1, 2, and 4 replicas, and reports per-replica utilization, p50/p99
request latency, and tokens/sec scaling efficiency.

**Virtual-clock semantics** (docs/cluster.md#benchmark): all replicas
share one host here, so the router steps them sequentially; a real
deployment steps them CONCURRENTLY.  The bench therefore charges each
cluster round at max(per-replica wall time for that round) — the
critical-path cost of the round — accumulated into a virtual clock.
Routing is deterministic, so round i does identical work on every
repeat of a drive; the bench runs each configuration several times and
takes the PER-ROUND elementwise min of the critical-path charge across
repeats (host scheduling jitter otherwise compounds through the max —
with 4 replicas a single slow outlier inflates the whole round).
Latency is measured in ticks (completion round - arrival tick), which
is exact and deterministic; throughput is tokens / virtual seconds.
The deterministic rounds-based speedup (rounds@1 / rounds@N) is
reported alongside as the noise-free backing number.

A second section compares the three routing policies at 2 replicas on
the same trace (round-robin / least-outstanding / prefix-affinity) and
reports the prefix-affinity hit rate — the Zipf skew means affinity
trades some load balance for page-pool prefix reuse.

Greedy outputs are asserted bit-identical across every replica count
and policy: routing chooses WHERE a request runs, never perturbs
per-replica numerics.
"""
import numpy as np

from benchmarks._common import emit_json, train_reduced

N_REQ = 48
N_PREFIXES = 8
PREFIX_LEN = 16          # 2 pages of 8 — the routable/cacheable unit
MAX_NEW = 16
PAGE_SIZE = 8
REPEATS = 5              # per-round elementwise-min across these drives

# acceptance gates (ISSUE PR 7): tokens/sec scaling on the bursty trace
GATES = {2: 1.7, 4: 3.0}


def build_trace(cfg, n_req=N_REQ, seed=0):
    """[(arrival_tick, prompt, prefix_id)] — Zipf-popular shared
    prefixes + unique tails, Poisson-burst arrivals (a high-rate tick
    every 3, low-rate background between)."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, PREFIX_LEN).astype(np.int32)
                for _ in range(N_PREFIXES)]
    w = 1.0 / np.arange(1, N_PREFIXES + 1) ** 1.1     # Zipf(1.1) popularity
    w /= w.sum()
    trace, tick = [], 0
    while len(trace) < n_req:
        lam = 12.0 if tick % 3 == 0 else 0.5
        for _ in range(min(rng.poisson(lam), n_req - len(trace))):
            k = int(rng.choice(N_PREFIXES, p=w))
            tail = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(4, 9))).astype(np.int32)
            trace.append((tick, np.concatenate([prefixes[k], tail]), k))
        tick += 1
    return trace


def _requests(trace):
    from repro.api import Request
    return [(t, Request(uid=i, prompt=p, max_new=MAX_NEW))
            for i, (t, p, _) in enumerate(trace)]


def drive(router, trace):
    """Feed the trace by arrival tick; one router.step() per tick.

    Returns (outputs, per_round_times, rounds, latency_ticks) where
    per_round_times[i] is max(per-replica wall time) of round i."""
    arrivals = _requests(trace)
    arrival_tick = {r.uid: t for t, r in arrivals}
    pending = list(arrivals)
    done_at = {}
    per_round = []
    while len(done_at) < len(arrivals):
        while pending and pending[0][0] <= router.rounds:
            router.submit(pending.pop(0)[1])
        progressed = router.step()
        per_round.append(max(router.last_step_times.values(), default=0.0))
        for uid in router.completed:
            done_at.setdefault(uid, router.rounds)
        if not progressed and not pending:
            raise AssertionError(
                f"cluster stalled: {len(done_at)}/{len(arrivals)} done")
    outs = {uid: list(r.out) for uid, r in router.completed.items()}
    lat = np.array([done_at[u] - arrival_tick[u] for u in sorted(done_at)])
    return outs, per_round, router.rounds, lat


def timed_drives(make_router, trace, repeats=REPEATS):
    """Repeat the (deterministic) drive; virtual time is the sum of the
    per-round elementwise min of the critical-path charge (module doc).

    Returns (outputs, virtual_seconds, rounds, latency_ticks, router)."""
    times, ref = [], None
    for _ in range(repeats):
        router = make_router()
        outs, per_round, rounds, lat = drive(router, trace)
        if ref is None:
            ref = (outs, rounds, lat, router)
        assert (outs, rounds) == (ref[0], ref[1]), "drive not deterministic"
        times.append(per_round)
    vt = float(np.sum(np.min(np.asarray(times), axis=0)))
    return ref[0], vt, ref[1], ref[2], ref[3]


def run(csv):
    from repro.api import LLM
    from repro.config.base import SPDPlanConfig

    cfg, canonical = train_reduced(steps=0)
    plan = SPDPlanConfig.first_k(cfg.n_layers, 2)
    llm = LLM.load(cfg, tp=2, engine="sim", plan=plan, params=canonical,
                   cache_len=64, max_batch=4, page_size=PAGE_SIZE,
                   num_pages=96, q_chunk=64)
    trace = build_trace(cfg)

    def cluster(n, policy="least-outstanding"):
        return llm.make_cluster(n, policy=policy)

    # warmup: one full discarded drive compiles every prefill bucket and
    # decode shape (all replica counts share the engine's jit cache)
    drive(cluster(1), trace)

    rows = []
    toks = ref_outs = base_vt = base_rounds = None
    for n in (1, 2, 4):
        outs, vt, rounds, lat, router = timed_drives(
            lambda: cluster(n), trace)
        if ref_outs is None:
            ref_outs, base_vt, base_rounds = outs, vt, rounds
            toks = sum(len(o) for o in outs.values())
        # routing must not perturb numerics: every replica count yields
        # the exact greedy streams of the single-replica run
        assert outs == ref_outs, f"outputs diverged at {n} replicas"
        tps = toks / vt
        row = {
            "replicas": n, "policy": "least-outstanding",
            "rounds": rounds, "virtual_s": vt, "tok_per_s": tps,
            "speedup_tok_per_s": (base_vt / vt),
            "speedup_rounds": base_rounds / rounds,
            "scaling_efficiency": (base_vt / vt) / n,
            "p50_latency_ticks": float(np.percentile(lat, 50)),
            "p99_latency_ticks": float(np.percentile(lat, 99)),
            "utilization": {rid: rep.stats()["utilization"]
                            for rid, rep in router.replicas.items()},
        }
        rows.append(row)
        csv(f"cluster/replicas{n}", vt * 1e6 / toks,
            f"tok/s={tps:.1f} speedup={row['speedup_tok_per_s']:.2f}x "
            f"rounds={rounds} p99={row['p99_latency_ticks']:.0f}ticks")
        gate = GATES.get(n)
        if gate:
            assert row["speedup_tok_per_s"] >= gate, \
                (n, row["speedup_tok_per_s"], gate)
            assert row["speedup_rounds"] >= gate * 0.9, \
                (n, row["speedup_rounds"], gate)

    # policy comparison at 2 replicas on the same trace
    for policy in ("round-robin", "least-outstanding", "prefix-affinity"):
        outs, vt, rounds, lat, router = timed_drives(
            lambda: cluster(2, policy=policy), trace, repeats=3)
        assert outs == ref_outs, f"outputs diverged under {policy}"
        st = router.stats()
        row = {"replicas": 2, "policy": policy, "rounds": rounds,
               "tok_per_s": toks / vt,
               "p99_latency_ticks": float(np.percentile(lat, 99))}
        if "prefix_affinity_hit_rate" in st:
            row["prefix_affinity_hit_rate"] = st["prefix_affinity_hit_rate"]
        rows.append(row)
        csv(f"cluster/policy_{policy}", vt * 1e6 / toks,
            f"rounds={rounds}"
            + (f" hit_rate={row['prefix_affinity_hit_rate']:.2f}"
               if "prefix_affinity_hit_rate" in row else ""))

    emit_json("cluster",
              {"arch": cfg.name, "n_req": N_REQ, "tp": 2, "engine": "sim",
               "replicas": [1, 2, 4], "max_new": MAX_NEW,
               "page_size": PAGE_SIZE, "prefix_len": PREFIX_LEN,
               "n_prefixes": N_PREFIXES, "trace": "zipf+poisson-burst"},
              rows)
    return rows
