"""Analytic per-device FLOP / HBM-byte models for the roofline terms.

WHY ANALYTIC: XLA's compiled cost_analysis() counts a lax.scan body ONCE,
not per trip — at 80 layers × 16 microbatches the module totals are off
by 2-4 orders of magnitude (the dry-run records them; roofline.py shows
the cross-check column).  The collective term does NOT have this problem:
the trace-time ledger is scan-aware.  Compute and memory terms therefore
come from first-principles models of the exact code we lowered:

FLOPs (global; /chips for the per-device term):
  matmul base       train 6·N_act·T, prefill 2·N_act·T, decode 2·N_act·B
  attention extra   causal: Σ_layers B·S·W_eff·H·(dh_qk+dh_v)·(3 if train)
                    (W_eff = min(S, window or S); decode: S·… per token)
  SSD extra         T·chunk·H·(P+2N)·(3 if train)

HBM bytes/device/step (what the weights+cache+activations force through
the 819 GB/s pipe — the roofline LOWER BOUND on traffic):
  decode   params_local + kv_cache_local          (weight/cache-bound)
  prefill  params_local + c_act·L·T_loc·d         (c_act ≈ 8 B r/w)
  train    (1+mb)·params_local·B_p + 3·opt_slice + c_act·L·T_loc·d·3
           (forward read per microbatch via FSDP gather, backward grads,
            AdamW slice read/write; activation traffic ×3 for fwd+bwd+
            remat recompute)
"""
from dataclasses import dataclass

from repro.config.base import ModelConfig, ShapeConfig
from repro.core.layer_kinds import layer_kinds


def attn_extra_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Quadratic attention + SSD-chunk flops NOT captured by 2·N·D."""
    kinds = layer_kinds(cfg)
    b, s = shape.global_batch, shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    total = 0.0
    for k in kinds:
        if k.mixer in ("gqa", "hybrid"):
            dh_qk = dh_v = cfg.d_head
            h = cfg.n_heads
            w_eff = min(s, k.window) if k.window else s
            if shape.kind == "decode":
                per = b * 1 * w_eff * h * (dh_qk + dh_v)
            else:
                per = b * s * (w_eff / 2) * h * (dh_qk + dh_v)
            total += 2 * per * mult
        if k.mixer == "mla":
            m = cfg.mla
            h = cfg.n_heads
            dh_qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            dh_v = m.v_head_dim
            if shape.kind == "decode":
                per = b * 1 * s * h * (dh_qk + dh_v)
            else:
                per = b * s * (s / 2) * h * (dh_qk + dh_v)
            total += 2 * per * mult
        if k.mixer in ("ssm", "hybrid") and cfg.ssm is not None:
            ss = cfg.ssm
            from repro.core.blocks import ssm_heads
            h = ssm_heads(cfg)
            toks = b * (1 if shape.kind == "decode" else s)
            q = 1 if shape.kind == "decode" else min(ss.chunk_size, s)
            per = toks * q * h * (ss.head_dim + 2 * ss.d_state)
            total += 2 * per * mult
    return total


def step_flops_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        base = 6.0 * n_act * shape.tokens
    elif shape.kind == "prefill":
        base = 2.0 * n_act * shape.tokens
    else:
        base = 2.0 * n_act * shape.global_batch
    return base + attn_extra_flops(cfg, shape)


def model_flops_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """'Useful' 6ND/2ND reference (no attention term) for the
    MODEL_FLOPS/HLO ratio."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens
    return 2.0 * n_act * shape.global_batch


@dataclass
class MemModel:
    params_local: float
    cache_local: float
    act_traffic: float
    opt_traffic: float
    total: float


def kv_cache_bytes_global(cfg: ModelConfig, shape: ShapeConfig, tp: int,
                          kv_bytes: float = 2.0) -> float:
    from repro.config.base import SPDPlanConfig
    from repro.core import model as M
    plan = SPDPlanConfig.none(cfg.n_layers)
    structs = M.cache_struct(cfg, plan, shape.global_batch, shape.seq_len, tp)
    tot = 0.0
    import jax
    for leaf in jax.tree.leaves(structs):
        n = 1
        for d in leaf.shape:
            n *= d
        tot += n * (kv_bytes if leaf.dtype.itemsize == 2 else
                    leaf.dtype.itemsize * kv_bytes / 2)
    return tot


def hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig, *, chips: int,
                         tp: int, microbatches: int = 1, fsdp: bool = True,
                         param_bytes: float = 2.0, kv_bytes: float = 2.0,
                         act_bytes: float = 2.0) -> MemModel:
    dp = chips // tp
    n_total = cfg.param_count()
    params_local = n_total * param_bytes / tp
    t_loc = shape.tokens / dp if shape.kind != "decode" else \
        shape.global_batch / min(dp, shape.global_batch)
    d = cfg.d_model
    L = cfg.n_layers
    cache_local = 0.0
    act = opt = 0.0
    if shape.kind == "decode":
        cache_local = kv_cache_bytes_global(cfg, shape, tp, kv_bytes) / chips
        total = params_local + cache_local
    elif shape.kind == "prefill":
        act = 8.0 * L * t_loc * d * act_bytes / 2
        total = params_local + act
    else:
        opt = 3.0 * 4.0 * n_total / tp / dp          # fp32 m/v/master slices
        wtraffic = (1 + microbatches) * params_local
        act = 3.0 * 6.0 * L * t_loc * d * act_bytes / 2
        total = wtraffic + opt + act
    return MemModel(params_local, cache_local, act, opt, total)
