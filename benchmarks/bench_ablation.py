"""Table 1 analog: SPD MLP-output residual-design ablation.

1a (no bias): attention-output residual Y_i added BEFORE the MLP
all-reduce (paper design: output = X + ΣY + ΣZ) vs AFTER (output =
X + Y_i + ΣZ: the unsynced Y_i is missing (tp-1)/tp of the heads).
1b (bias): bias residual added AFTER the all-reduce (paper design:
counted once) vs BEFORE (counted tp times).

Measured as WikiText2-analog perplexity with SPD on the FIRST block only,
everything else TP — exactly the paper's setting."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._common import Timer, emit_json, quality, train_reduced
from repro.config.base import SPDPlanConfig
from repro.core import model as M, simtp
from repro.core.blocks import (gqa_mixer_seq, layer_specs, pad_layer)
from repro.core.layer_kinds import layer_kinds
from repro.data.synthetic import calibration_batches
from repro.models.common import layernorm, rmsnorm
from repro.parallel.layout import make_gqa_layout


def _variant_block(cfg, kind, split, x, tp, variant):
    """Per-shard manual SPD block with a chosen residual design."""
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    lay = make_gqa_layout(cfg.n_heads, cfg.n_kv_heads, tp)

    def norm(h, p):
        if cfg.norm == "layernorm":
            return layernorm(h, p["w"], p["b"], cfg.norm_eps)
        return rmsnorm(h, p["w"], cfg.norm_eps)

    def mixer(p):
        h = norm(x, p["ln1"])
        part, _ = gqa_mixer_seq(cfg, kind, p["attn"], h, pos, lay, "model",
                                q_chunk=64)
        return part

    parts = jax.vmap(mixer, axis_name="model")(split)        # (tp,B,S,d) P_i
    bo = split["attn"]["bo"][0] if "bo" in split["attn"] else None

    def ffn(p, u):
        h2 = norm(u, p["ln2"])
        up = h2 @ p["mlp"]["wu"]
        if cfg.mlp_bias:
            up = up + p["mlp"]["bu"]
        if cfg.gated_mlp:
            g = h2 @ p["mlp"]["wg"]
            hid = jax.nn.silu(g) * up
        else:
            hid = jax.nn.relu(up) if cfg.act == "relu" else jax.nn.gelu(up)
        return hid @ p["mlp"]["wd"]

    y_i = parts + (bo if bo is not None else 0.0)
    u = x[None] + y_i
    z = jax.vmap(ffn, in_axes=(0, 0))(split, u)
    bd = split["mlp"]["bd"][0] if cfg.mlp_bias else 0.0

    if variant == "attn_before_ar":       # paper design (Fig 3a/3b)
        out = x + parts.sum(0) + z.sum(0) + (bo if bo is not None else 0.0)
    elif variant == "attn_after_ar":      # Table 1a wrong choice
        out = x + parts[0] + z.sum(0) + (bo if bo is not None else 0.0)
    elif variant == "bias_after_ar":      # paper design for 1b == 3b
        out = x + parts.sum(0) + bo + z.sum(0)
    elif variant == "bias_before_ar":     # Table 1b wrong: b summed tp times
        out = x + (parts + bo).sum(0) + z.sum(0)
    else:
        raise ValueError(variant)
    return out + bd


def _ppl_with_block0_variant(cfg, canonical, tp, calib, variant):
    """Full-model ppl with block 0 replaced by a variant SPD block."""
    kind = layer_kinds(cfg)[0]
    plan = SPDPlanConfig.none(cfg.n_layers)
    split_model = simtp.prepare_params(canonical, cfg, plan, tp)
    split_l0 = simtp._split_with_offset(
        pad_layer(canonical["layers"][0], cfg, kind, tp),
        layer_specs(cfg, kind), tp, 0)

    tot_ce = tot_n = 0.0
    from repro.core.spd import capture_block_inputs
    padded = M.pad_model(canonical, cfg, tp)
    for batch in calib:
        toks = jnp.asarray(batch["tokens"])
        # embedding
        hid = capture_block_inputs(cfg, padded, tp, [batch], q_chunk=64)[0]
        x0 = jnp.asarray(hid[0])
        x1 = _variant_block(cfg, kind, split_l0, x0, tp, variant)
        # remaining layers in TP via per-layer block fns
        x = x1
        b, s = x.shape[:2]
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        for li in range(1, cfg.n_layers):
            k_i = layer_kinds(cfg)[li]
            sp = simtp._split_with_offset(
                pad_layer(canonical["layers"][li], cfg, k_i, tp),
                layer_specs(cfg, k_i), tp, 0)
            fn = simtp.make_block_fn(cfg, k_i, tp, drop=False, q_chunk=64)
            x = fn(sp, x, pos)
        # head + ce (single device math on full logits)
        from repro.models.common import layernorm as ln, rmsnorm as rn
        lnf = canonical["lnf"]
        xf = (ln(x, lnf["w"], lnf["b"], cfg.norm_eps)
              if cfg.norm == "layernorm" else rn(x, lnf["w"], cfg.norm_eps))
        w = canonical["emb"].T if cfg.tie_embeddings else canonical["head"]
        logits = (xf @ w).astype(jnp.float32)
        lbl = jnp.asarray(batch["labels"])
        lse = jax.nn.logsumexp(logits, -1)
        pick = jnp.take_along_axis(logits, lbl[..., None], -1)[..., 0]
        tot_ce += float(jnp.sum(lse - pick))
        tot_n += lbl.size
    return float(np.exp(tot_ce / tot_n))


def run(csv):
    rows = []
    # Table 1a: no-bias model (llama2 analog)
    cfg_a, canon_a = train_reduced("llama2-7b", steps=80)
    calib = calibration_batches(cfg_a.vocab_size, 8, 48, batch=8)[:1]
    base_plan = SPDPlanConfig.none(cfg_a.n_layers)
    ppl_base, _ = quality(cfg_a, canon_a, base_plan, 2, calib)
    csv("ablation/1a_no_spd", 0, f"ppl={ppl_base:.3f}")
    for variant in ("attn_before_ar", "attn_after_ar"):
        t = Timer()
        ppl = _ppl_with_block0_variant(cfg_a, canon_a, 2, calib, variant)
        csv(f"ablation/1a_{variant}", t.us(), f"ppl={ppl:.3f}")
        rows.append({"table": "1a", "variant": variant, "ppl": ppl})
    assert rows[0]["ppl"] <= rows[1]["ppl"], rows   # paper's choice wins

    # Table 1b: bias model (OPT analog).  At reduced scale the LEARNED
    # out-proj bias is near zero after 80 steps, so the two designs tie;
    # the paper's 70x effect (13.07 vs 332.60 ppl) comes from a trained
    # 6.7B bias.  We therefore test the MECHANISM structurally: boost the
    # bias to a realistic magnitude — counting it tp x (before-AR, wrong)
    # must then clearly lose to counting it once (after-AR, paper design).
    cfg_b, canon_b = train_reduced("opt-6.7b", steps=80)
    calib_b = calibration_batches(cfg_b.vocab_size, 8, 48, batch=8)[:1]
    ppl_base_b, _ = quality(cfg_b, canon_b,
                            SPDPlanConfig.none(cfg_b.n_layers), 2, calib_b)
    csv("ablation/1b_no_spd", 0, f"ppl={ppl_base_b:.3f}")
    import jax as _jax
    boosted = dict(canon_b)
    layers = list(canon_b["layers"])
    l0 = _jax.tree.map(lambda x: x, layers[0])
    a0 = dict(l0["attn"])
    key = _jax.random.PRNGKey(5)
    a0["bo"] = a0["bo"] + 0.2 * _jax.random.normal(key, a0["bo"].shape,
                                                   a0["bo"].dtype)
    l0 = dict(l0); l0["attn"] = a0
    layers[0] = l0
    boosted["layers"] = layers
    got = []
    for variant in ("bias_after_ar", "bias_before_ar"):
        t = Timer()
        ppl = _ppl_with_block0_variant(cfg_b, boosted, 2, calib_b, variant)
        csv(f"ablation/1b_{variant}", t.us(), f"ppl={ppl:.3f}")
        got.append({"table": "1b", "variant": variant, "ppl": ppl})
    rows += got
    assert got[0]["ppl"] < got[1]["ppl"], got       # paper's choice wins
    emit_json("ablation", {"archs": [cfg_a.name, cfg_b.name], "tp": 2},
              rows)
    return rows
