"""Self-speculative decoding: acceptance rate, tokens per verify round,
and the ledger-measured wire bytes the SPD draft saves.

Two sections (docs/speculative.md has the model):

  * serve: reduced-smollm greedy serving through the facade with spec on
    (`all-drop` and `drop+quant4` drafts) vs plain decoding — reports
    the measured acceptance rate and tokens/verify-round (> 1.0 means
    each multi-token verify replaces more than one sequential decode
    step, which is the latency win: one sync ROUND per block instead of
    one per token).

  * wire at TP in {2, 4, 8}: trace-time collective-ledger bytes of one
    draft decode step under each preset vs the same step at exact comm.
    Speculation's extra forwards are the k draft passes; SPD is what
    makes them nearly free on the wire, and `draft_wire_saved_bytes_per
    _tok` prices that: k * (exact_step - draft_step bytes) amortized
    over the measured tokens/round.  (Total spec bytes per token exceed
    plain decoding — the win is fewer sequential sync rounds, not fewer
    bytes; the draft saving is the part SPD contributes.)
"""
import jax.numpy as jnp
import numpy as np

from benchmarks._common import (Timer, emit_json, ledger_wire_bytes,
                                train_reduced)
from repro.config.base import SPDPlanConfig
from repro.core import simtp
from repro.parallel.collectives import collective_ledger
from repro.runtime.engines import SimEngine

TPS = (2, 4, 8)
K = 3
DRAFTS = ("all-drop", "drop+quant4")
BENCH_JSON_ROOT = None      # repo root by default; tests redirect it


def decode_step_ledger(cfg, canonical, plan, tp):
    """Collective ledger of ONE single-token decode step under `plan`
    (fresh engine so the trace is captured, not replayed from cache)."""
    split = simtp.prepare_params(canonical, cfg, plan, tp)
    eng = SimEngine(cfg, plan, tp, q_chunk=64)
    caches = eng.blank_caches(1, 32)
    with collective_ledger() as led:
        eng.decode(split, jnp.zeros((1, 1), jnp.int32),
                   jnp.ones((1,), jnp.int32), caches)
    return led


def run(csv):
    from repro.api import LLM, SamplingParams, SpecConfig
    from repro.spec import derive_draft_plan

    cfg, canonical = train_reduced(steps=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(4, 16, 8)]
    sp = SamplingParams(max_new=16)
    rows = []

    # ---- measured serving: spec vs plain greedy (sim, tp=2) ----
    plain = LLM.load(cfg, tp=2, engine="sim", params=canonical,
                     cache_len=64, max_batch=4, q_chunk=64)
    ref = [o.token_ids for o in plain.generate(prompts, sp)]   # warm + ref
    tps_meas = {}
    for draft in DRAFTS:
        llm = LLM.load(cfg, tp=2, engine="sim", params=canonical,
                       cache_len=64, max_batch=4, q_chunk=64,
                       spec=SpecConfig(k=K, draft=draft))
        outs = llm.generate(prompts, sp)                        # warm
        assert [o.token_ids for o in outs] == ref, "greedy spec must be exact"
        # timed run on a fresh scheduler over the already-compiled steps
        from repro.api import Request
        sched = llm.serve(max_batch=4)
        for uid, p in enumerate(prompts):
            sched.submit(Request(uid=uid, prompt=p, max_new=sp.max_new))
        t = Timer()
        sched.run()
        us = t.us()
        acc = sched.spec_acceptance
        tps = sched.spec_tokens_per_step
        tps_meas[draft] = tps
        assert tps > 1.0, (draft, tps)
        rows.append({"kind": "serve", "draft": draft, "k": K,
                     "acceptance": acc, "tokens_per_step": tps,
                     "rounds": sched.spec_rounds})
        csv(f"spec/serve/{draft}", us,
            f"accept={acc:.3f} tok_per_step={tps:.3f} "
            f"rounds={sched.spec_rounds}")

    # ---- wire bytes: SPD draft step vs exact-comm step, TP 2/4/8 ----
    for tp in TPS:
        exact_led = decode_step_ledger(
            cfg, canonical, SPDPlanConfig.none(cfg.n_layers), tp)
        exact_b = ledger_wire_bytes(exact_led, tp)
        for draft in DRAFTS:
            dplan = derive_draft_plan(cfg, SpecConfig(k=K, draft=draft))
            draft_b = ledger_wire_bytes(
                decode_step_ledger(cfg, canonical, dplan, tp), tp)
            assert draft_b < exact_b, (tp, draft, draft_b, exact_b)
            saved_tok = K * (exact_b - draft_b) / tps_meas[draft]
            rows.append({"kind": "wire", "tp": tp, "draft": draft,
                         "exact_step_bytes": exact_b,
                         "draft_step_bytes": draft_b,
                         "draft_vs_exact": exact_b / max(draft_b, 1.0),
                         "draft_wire_saved_bytes_per_tok": saved_tok})
            csv(f"spec/wire/tp{tp}/{draft}", 0.0,
                f"draft_bytes={draft_b:.0f} exact_bytes={exact_b:.0f} "
                f"saved_per_tok={saved_tok:.0f}")

    emit_json("spec", {"arch": cfg.name, "k": K, "drafts": list(DRAFTS),
                       "tps": list(TPS), "requests": len(prompts),
                       "max_new": sp.max_new},
              rows, root=BENCH_JSON_ROOT)
    return rows
