"""Self-speculative decoding: acceptance rate, tokens per verify round,
and the ledger-measured wire bytes the SPD draft saves.

Two sections (docs/speculative.md has the model):

  * serve: reduced-smollm greedy serving through the facade with spec on
    vs plain decoding, across the ladder the subsystem grew —
      all-drop                       the paper's 100% SPD point (chain)
      calibrated                     the measured cheapest-qualifying
                                     draft policy (spec/calibrate.py)
      calibrated+adaptive            + per-request k in [1, K_MAX]
      calibrated+adaptive+tree       + depth-1 tree verification
    Every variant is asserted token-identical to plain greedy; reported
    acceptance and tokens/verify-round (> 1.0 means each multi-token
    verify replaces more than one sequential decode step — the latency
    win: one sync ROUND per block instead of one per token).  The
    calibrated rows are what scripts/check_spec_bench.py gates
    (tokens/step >= 1.8, acceptance >= 0.45).

  * wire at TP in {2, 4, 8}: trace-time collective-ledger bytes of one
    draft decode step under each policy (presets + the calibrated
    winner) vs the same step at exact comm.  Speculation's extra
    forwards are the k draft passes; SPD is what makes them nearly free
    on the wire, and `draft_wire_saved_bytes_per_tok` prices that:
    k * (exact_step - draft_step bytes) amortized over the measured
    tokens/round.  (Total spec bytes per token exceed plain decoding —
    the win is fewer sequential sync rounds, not fewer bytes; the draft
    saving is the part SPD contributes.)
"""
import jax.numpy as jnp
import numpy as np

from benchmarks._common import (Timer, emit_json, ledger_wire_bytes,
                                train_reduced)
from repro.config.base import SPDPlanConfig
from repro.core import simtp
from repro.parallel.collectives import collective_ledger
from repro.runtime.engines import SimEngine

TPS = (2, 4, 8)
K = 3
K_MAX = 5
PRESET_DRAFTS = ("all-drop", "drop+quant4")
BENCH_JSON_ROOT = None      # repo root by default; tests redirect it


def decode_step_ledger(cfg, canonical, plan, tp):
    """Collective ledger of ONE single-token decode step under `plan`
    (fresh engine so the trace is captured, not replayed from cache)."""
    split = simtp.prepare_params(canonical, cfg, plan, tp)
    eng = SimEngine(cfg, plan, tp, q_chunk=64)
    caches = eng.blank_caches(1, 32)
    with collective_ledger() as led:
        eng.decode(split, jnp.zeros((1, 1), jnp.int32),
                   jnp.ones((1,), jnp.int32), caches)
    return led


def _serve_variants():
    """(row name, SpecConfig kwargs) for the serve ladder."""
    return [
        ("all-drop", dict(k=K, draft="all-drop")),
        ("calibrated", dict(k=K, draft="calibrated")),
        ("calibrated+adaptive",
         dict(k=K, draft="calibrated", adaptive=True, k_min=1,
              k_max=K_MAX)),
        ("calibrated+adaptive+tree",
         dict(k=K, draft="calibrated", adaptive=True, k_min=1,
              k_max=K_MAX, tree_width=2)),
    ]


def run(csv):
    from repro.api import LLM, Request, SamplingParams, SpecConfig
    from repro.spec import derive_draft_plan
    from repro.spec.calibrate import clear_cache

    cfg, canonical = train_reduced(steps=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in rng.integers(4, 16, 8)]
    # held-out prompts for the policy search — disjoint from the served
    # set so measured acceptance is not fit to the serving workload
    crng = np.random.default_rng(1_000_003)
    calib = [crng.integers(0, cfg.vocab_size, 12).astype(np.int32)
             for _ in range(3)]
    sp = SamplingParams(max_new=16)
    rows = []

    # ---- measured serving: spec ladder vs plain greedy (sim, tp=2) ----
    plain = LLM.load(cfg, tp=2, engine="sim", params=canonical,
                     cache_len=64, max_batch=4, q_chunk=64)
    ref = [o.token_ids for o in plain.generate(prompts, sp)]   # warm + ref
    clear_cache()           # measure THIS canonical tree, not a stale run
    cal = None
    tps_meas = {}
    for name, kw in _serve_variants():
        llm = LLM.load(cfg, tp=2, engine="sim", params=canonical,
                       cache_len=64, max_batch=4, q_chunk=64)
        llm.enable_spec(SpecConfig(**kw), calib_prompts=calib)
        if llm.spec_calibration is not None:
            cal = llm.spec_calibration     # cached across the variants
        outs = llm.generate(prompts, sp)                        # warm
        assert [o.token_ids for o in outs] == ref, \
            f"greedy spec must be exact ({name})"
        # timed run on a fresh scheduler over the already-compiled steps
        sched = llm.serve(max_batch=4)
        for uid, p in enumerate(prompts):
            sched.submit(Request(uid=uid, prompt=p, max_new=sp.max_new))
        t = Timer()
        sched.run()
        us = t.us()
        acc = sched.spec_acceptance
        tps = sched.spec_tokens_per_step
        tps_meas[name] = tps
        assert tps > 1.0, (name, tps)
        row = {"kind": "serve", "draft": name, "k": K,
               "acceptance": acc, "tokens_per_step": tps,
               "rounds": sched.spec_rounds,
               "adaptive": bool(kw.get("adaptive", False)),
               "tree_width": kw.get("tree_width", 1),
               "alt_commits": sched.spec_alt_commits}
        if llm.spec_calibration is not None:
            row["policy"] = llm.spec_calibration.name
        rows.append(row)
        csv(f"spec/serve/{name}", us,
            f"accept={acc:.3f} tok_per_step={tps:.3f} "
            f"rounds={sched.spec_rounds}")

    # ---- wire bytes: draft step vs exact-comm step, TP 2/4/8 ----
    wire_plans = [(d, derive_draft_plan(cfg, SpecConfig(k=K, draft=d)))
                  for d in PRESET_DRAFTS]
    wire_plans.append(("calibrated", cal.policy))
    for tp in TPS:
        exact_led = decode_step_ledger(
            cfg, canonical, SPDPlanConfig.none(cfg.n_layers), tp)
        exact_b = ledger_wire_bytes(exact_led, tp)
        for draft, dplan in wire_plans:
            draft_b = ledger_wire_bytes(
                decode_step_ledger(cfg, canonical, dplan, tp), tp)
            assert draft_b < exact_b, (tp, draft, draft_b, exact_b)
            tps_ref = tps_meas.get(draft, tps_meas["calibrated"])
            saved_tok = K * (exact_b - draft_b) / tps_ref
            row = {"kind": "wire", "tp": tp, "draft": draft,
                   "exact_step_bytes": exact_b,
                   "draft_step_bytes": draft_b,
                   "draft_vs_exact": exact_b / max(draft_b, 1.0),
                   "draft_wire_saved_bytes_per_tok": saved_tok}
            if draft == "calibrated":
                row["policy"] = cal.name
            rows.append(row)
            csv(f"spec/wire/tp{tp}/{draft}", 0.0,
                f"draft_bytes={draft_b:.0f} exact_bytes={exact_b:.0f} "
                f"saved_per_tok={saved_tok:.0f}")

    emit_json("spec",
              {"arch": cfg.name, "k": K, "k_max": K_MAX,
               "drafts": [n for n, _ in _serve_variants()],
               "calibrated_policy": cal.name,
               "calib_trials": [list(t) for t in cal.trials],
               "tps": list(TPS), "requests": len(prompts),
               "max_new": sp.max_new},
              rows, root=BENCH_JSON_ROOT)
    return rows
