"""Serving throughput: dense slot caches vs the paged KV pool, driven
through the `repro.api` facade (one `LLM`, two `CacheConfig`s).

Skewed prompt lengths (a few long, many short — the realistic traffic
shape) on the SimEngine: the dense scheduler must budget every slot for
the WORST-CASE sequence, so its admissible batch is small; the paged
scheduler admits against free pages, packs more concurrent requests into
the same token memory, and preempts/requeues when the pool runs dry.
Reports tokens/sec of generated output plus the cache-memory footprint
each configuration pre-allocates (docs/serving.md has the design).

A second section drives a shared-prefix arrival trace (every request
carries the same system prefix + a short unique tail) and reports the
admission prefill latency cold (empty pool, full prefill) vs on a
prefix-cache hit (resident pages shared, only the tail prefilled) —
the serving win of docs/serving.md#prefix-caching.
"""
import numpy as np

from benchmarks._common import Timer, emit_json, train_reduced


def _requests(cfg, n, seed=0):
    """Skewed mix: ~1/4 long prompts, the rest short."""
    from repro.api import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(24, 48)) if uid % 4 == 0 \
            else int(rng.integers(4, 12))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=8))
    return reqs


def _tok_bytes(caches):
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


def run(csv):
    from repro.api import LLM
    from repro.config.base import SPDPlanConfig

    cfg, canonical = train_reduced(steps=0)
    plan = SPDPlanConfig.first_k(cfg.n_layers, 2)
    n_req, cache_len = 16, 64
    llm = LLM.load(cfg, tp=2, engine="sim", plan=plan, params=canonical,
                   cache_len=cache_len, max_batch=4, q_chunk=64)
    rows = []

    def drive(sched, name):
        # warmup with the SAME mix so every prefill bucket / decode shape
        # is compiled before the timed run (steady-state comparison)
        for r in _requests(cfg, n_req):
            sched.submit(r)
        sched.run()
        sched.completed.clear()
        sched.n_preemptions = 0          # report the timed run only
        for r in _requests(cfg, n_req):
            sched.submit(r)
        t = Timer()
        done = sched.run()
        us = t.us()
        toks = sum(len(r.out) for r in done.values())
        assert len(done) == n_req, (name, len(done))
        return toks, us

    # dense: every slot pre-allocates cache_len tokens
    dense = llm.serve()
    dense_bytes = _tok_bytes(dense.caches)
    toks_d, us_d = drive(dense, "dense")
    tps_d = toks_d / (us_d / 1e6)
    rows.append({"mode": "dense", "tok_per_s": tps_d,
                 "cache_mb": dense_bytes / 2**20})
    csv("serving/dense", us_d / toks_d,
        f"tok/s={tps_d:.1f} cache_mb={dense_bytes / 2**20:.2f}")

    # paged: ~2.5 dense slots' worth of token memory but 8 schedulable
    # slots — throughput comes from packing short prompts into pages
    paged = llm.serve(max_batch=8, page_size=8, num_pages=20,
                      prefill_chunk=16)
    paged_bytes = _tok_bytes(paged.pcaches)
    toks_p, us_p = drive(paged, "paged")
    tps_p = toks_p / (us_p / 1e6)
    rows.append({"mode": "paged", "tok_per_s": tps_p,
                 "cache_mb": paged_bytes / 2**20,
                 "preemptions": paged.n_preemptions})
    csv("serving/paged", us_p / toks_p,
        f"tok/s={tps_p:.1f} cache_mb={paged_bytes / 2**20:.2f} "
        f"preempt={paged.n_preemptions}")
    rows.append({"mode": "ratio", "paged_over_dense": tps_p / tps_d})
    csv("serving/ratio", 0.0, f"paged/dense tok/s = {tps_p / tps_d:.2f}")

    # shared-prefix arrival trace: every request carries the same
    # 112-token system prefix + a short unique tail.  Admission latency
    # cold (full prefill through the 128-wide pow2 prompt bucket) vs on
    # a prefix-cache hit (shared pages + an 8-wide suffix-only
    # prefill); min-of-3 to shed scheduler-step timing noise.
    from repro.api import Request
    rng = np.random.default_rng(3)
    pkw = dict(cache_len=128, max_batch=4, page_size=8, num_pages=64)
    base = rng.integers(0, cfg.vocab_size, 112).astype(np.int32)

    def prefix_req(uid):
        tail = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        return Request(uid=uid, prompt=np.concatenate([base, tail]),
                       max_new=8)

    def admit_us(sched, req):
        """Time the step that admits (and prefills) `req`, drain after."""
        sched.submit(req)
        t = Timer()
        sched.step()
        us = t.us()
        sched.run()
        return us

    warm = llm.serve(**pkw)
    assert warm.kv.prefix_cache
    # warmup compiles BOTH admission paths (cold bucket + warm suffix)
    admit_us(warm, prefix_req(0))
    admit_us(warm, prefix_req(1))
    assert warm.kv.prefix_hits == 1
    # cold: a fresh pool each time, same engine (compiled steps shared)
    cold_us = min(admit_us(llm.serve(**pkw), prefix_req(100 + i))
                  for i in range(3))
    warm_us = min(admit_us(warm, prefix_req(2 + i)) for i in range(3))
    assert warm.kv.prefix_hits == 4
    assert warm.kv.prefix_tokens_reused >= 4 * 112
    assert warm_us < cold_us, (warm_us, cold_us)
    rows.append({"mode": "prefix_cold", "prefill_us": cold_us})
    rows.append({"mode": "prefix_warm", "prefill_us": warm_us,
                 "hits": warm.kv.prefix_hits,
                 "tokens_reused": warm.kv.prefix_tokens_reused,
                 "cold_over_warm": cold_us / warm_us})
    csv("serving/prefix_cold", cold_us, "full prefill, empty pool")
    csv("serving/prefix_warm", warm_us,
        f"cache-hit prefill, speedup={cold_us / warm_us:.2f}x "
        f"reused={warm.kv.prefix_tokens_reused}tok")

    # decode steps DONATE the KV cache (runtime/forward.py StepSpec):
    # after one step the input cache buffers must be gone — reused in
    # place, not copied.  jax deletes donated buffers even where XLA
    # ends up copying, so pair it with the compile-time aliasing count.
    import jax
    import jax.numpy as jnp
    cs = llm.engine.blank_caches(4, cache_len)
    leaves = jax.tree.leaves(cs)
    _, cs2 = llm.engine.decode(llm.params, jnp.zeros((4, 1), jnp.int32),
                               jnp.zeros((4,), jnp.int32), cs)
    assert all(leaf.is_deleted() for leaf in leaves), \
        "dense decode no longer donates its KV cache"
    pcs = llm.engine.blank_paged_caches(4, cache_len, page_size=8,
                                        num_pages=20)
    pleaves = jax.tree.leaves(pcs)
    table = jnp.full((4, cache_len // 8), -1, jnp.int32)
    _, pcs2 = llm.engine.decode_paged(
        llm.params, jnp.zeros((4, 1), jnp.int32),
        jnp.zeros((4,), jnp.int32), table, pcs)
    assert all(leaf.is_deleted() for leaf in pleaves), \
        "paged decode no longer donates its KV cache"
    rows.append({"mode": "donation", "dense_cache_donated": True,
                 "paged_cache_donated": True})
    csv("serving/donation", 0.0, "decode steps donate the KV cache")

    emit_json("serving", {"arch": cfg.name, "n_req": n_req,
                          "cache_len": cache_len, "tp": 2,
                          "engine": "sim"}, rows)
    return rows
