"""Serving throughput: dense slot caches vs the paged KV pool, driven
through the `repro.api` facade (one `LLM`, two `CacheConfig`s).

Skewed prompt lengths (a few long, many short — the realistic traffic
shape) on the SimEngine: the dense scheduler must budget every slot for
the WORST-CASE sequence, so its admissible batch is small; the paged
scheduler admits against free pages, packs more concurrent requests into
the same token memory, and preempts/requeues when the pool runs dry.
Reports tokens/sec of generated output plus the cache-memory footprint
each configuration pre-allocates (docs/serving.md has the design).
"""
import numpy as np

from benchmarks._common import Timer, emit_json, train_reduced


def _requests(cfg, n, seed=0):
    """Skewed mix: ~1/4 long prompts, the rest short."""
    from repro.api import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(24, 48)) if uid % 4 == 0 \
            else int(rng.integers(4, 12))
        reqs.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new=8))
    return reqs


def _tok_bytes(caches):
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(caches))


def run(csv):
    from repro.api import LLM
    from repro.config.base import SPDPlanConfig

    cfg, canonical = train_reduced(steps=0)
    plan = SPDPlanConfig.first_k(cfg.n_layers, 2)
    n_req, cache_len = 16, 64
    llm = LLM.load(cfg, tp=2, engine="sim", plan=plan, params=canonical,
                   cache_len=cache_len, max_batch=4, q_chunk=64)
    rows = []

    def drive(sched, name):
        # warmup with the SAME mix so every prefill bucket / decode shape
        # is compiled before the timed run (steady-state comparison)
        for r in _requests(cfg, n_req):
            sched.submit(r)
        sched.run()
        sched.completed.clear()
        sched.n_preemptions = 0          # report the timed run only
        for r in _requests(cfg, n_req):
            sched.submit(r)
        t = Timer()
        done = sched.run()
        us = t.us()
        toks = sum(len(r.out) for r in done.values())
        assert len(done) == n_req, (name, len(done))
        return toks, us

    # dense: every slot pre-allocates cache_len tokens
    dense = llm.serve()
    dense_bytes = _tok_bytes(dense.caches)
    toks_d, us_d = drive(dense, "dense")
    tps_d = toks_d / (us_d / 1e6)
    rows.append({"mode": "dense", "tok_per_s": tps_d,
                 "cache_mb": dense_bytes / 2**20})
    csv("serving/dense", us_d / toks_d,
        f"tok/s={tps_d:.1f} cache_mb={dense_bytes / 2**20:.2f}")

    # paged: ~2.5 dense slots' worth of token memory but 8 schedulable
    # slots — throughput comes from packing short prompts into pages
    paged = llm.serve(max_batch=8, page_size=8, num_pages=20,
                      prefill_chunk=16)
    paged_bytes = _tok_bytes(paged.pcaches)
    toks_p, us_p = drive(paged, "paged")
    tps_p = toks_p / (us_p / 1e6)
    rows.append({"mode": "paged", "tok_per_s": tps_p,
                 "cache_mb": paged_bytes / 2**20,
                 "preemptions": paged.n_preemptions})
    csv("serving/paged", us_p / toks_p,
        f"tok/s={tps_p:.1f} cache_mb={paged_bytes / 2**20:.2f} "
        f"preempt={paged.n_preemptions}")
    rows.append({"mode": "ratio", "paged_over_dense": tps_p / tps_d})
    csv("serving/ratio", 0.0, f"paged/dense tok/s = {tps_p / tps_d:.2f}")

    # decode steps DONATE the KV cache (runtime/forward.py StepSpec):
    # after one step the input cache buffers must be gone — reused in
    # place, not copied.  jax deletes donated buffers even where XLA
    # ends up copying, so pair it with the compile-time aliasing count.
    import jax
    import jax.numpy as jnp
    cs = llm.engine.blank_caches(4, cache_len)
    leaves = jax.tree.leaves(cs)
    _, cs2 = llm.engine.decode(llm.params, jnp.zeros((4, 1), jnp.int32),
                               jnp.zeros((4,), jnp.int32), cs)
    assert all(leaf.is_deleted() for leaf in leaves), \
        "dense decode no longer donates its KV cache"
    pcs = llm.engine.blank_paged_caches(4, cache_len, page_size=8,
                                        num_pages=20)
    pleaves = jax.tree.leaves(pcs)
    table = jnp.full((4, cache_len // 8), -1, jnp.int32)
    _, pcs2 = llm.engine.decode_paged(
        llm.params, jnp.zeros((4, 1), jnp.int32),
        jnp.zeros((4,), jnp.int32), table, pcs)
    assert all(leaf.is_deleted() for leaf in pleaves), \
        "paged decode no longer donates its KV cache"
    rows.append({"mode": "donation", "dense_cache_donated": True,
                 "paged_cache_donated": True})
    csv("serving/donation", 0.0, "decode steps donate the KV cache")

    emit_json("serving", {"arch": cfg.name, "n_req": n_req,
                          "cache_len": cache_len, "tp": 2,
                          "engine": "sim"}, rows)
    return rows
