"""Fig 2 analog: per-step data-transfer time vs SPD%, HBW vs LBW.

The paper measures all-reduce kernel time on A100 nodes; without TPUs we
compute the same quantity analytically: exact per-step collective payload
bytes from the trace-time ledger (scan-aware), through a ring-all-reduce
time model at HBW (ICI 50 GB/s) and LBW (10 GB/s) — the claim under test
is STRUCTURAL: 100% SPD halves sync-point count and removes ~50% of
sync-able bytes, monotonically in SPD%."""
import jax.numpy as jnp
import numpy as np

from benchmarks._common import HW, Timer, ring_all_reduce_time
from repro.config.base import SPDPlanConfig, replace
from repro.configs import get_config
from repro.core import model as M, simtp
from repro.parallel.collectives import collective_ledger


def transfer_bytes(cfg, plan, tp, b=1, s=128):
    """Ledger bytes for one batch-1 seq-128 forward (paper Fig 2 input)."""
    import jax
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    split = simtp.prepare_params(params, cfg, plan, tp)
    toks = jnp.zeros((b, s), jnp.int32)
    with collective_ledger() as led:
        fn = simtp.make_logits_fn(cfg, plan, tp, q_chunk=128)
        fn(split, toks, None)
    return sum(n for op, ax, n in led if op == "all-reduce"), led


def run(csv):
    # reduced llama2 stands in for LLaMA2-70B; the BYTES RATIO vs SPD% is
    # scale-free (both attention and MLP syncs move B*S*d each)
    cfg = replace(get_config("llama2-7b", reduced=True), dtype="float32")
    tp = 8
    rows = []
    base_bytes = None
    for pct in (0, 25, 50, 75, 100):
        k = int(round(cfg.n_layers * pct / 100))
        plan = SPDPlanConfig.first_k(cfg.n_layers, k)
        t = Timer()
        nbytes, led = transfer_bytes(cfg, plan, tp)
        us = t.us()
        if base_bytes is None:
            base_bytes = nbytes
        t_hbw = ring_all_reduce_time(nbytes, tp, HW["hbw_eff"]) * 1e6
        t_lbw = ring_all_reduce_time(nbytes, tp, HW["lbw_eff"]) * 1e6
        red = 100 * (1 - nbytes / base_bytes)
        csv(f"transfer/spd{pct}", us,
            f"bytes={nbytes} reduction={red:.1f}% "
            f"t_hbw_us={t_hbw:.1f} t_lbw_us={t_lbw:.1f}")
        rows.append({"spd_pct": pct, "bytes": nbytes, "red_pct": red,
                     "t_hbw_us": t_hbw, "t_lbw_us": t_lbw})
    # paper's headline: 100% SPD removes >=46% of transfer in all settings
    assert rows[-1]["red_pct"] >= 40.0, rows[-1]
    return rows
