"""Fig 2 analog: per-step data-transfer time vs SPD%, HBW vs LBW — now
extended with the per-block comm policy (drop | quant8 | quant4 | exact).

The paper measures all-reduce kernel time on A100 nodes; without TPUs we
compute the same quantity analytically: exact per-step collective wire
bytes from the trace-time ledger (scan-aware, quantization-aware),
through ring-collective time models at HBW (ICI 50 GB/s) and LBW
(10 GB/s).  The analytic model reads EVERY byte from the ledger — no
shape recomputation — so quantized syncs (which log as a low-bit
reduce-scatter + all-gather pair) are priced at their true wire format.

Claims under test:
  * 100% SPD removes >=40% of sync-able wire bytes (paper, structural);
  * quant8 cuts kept-sync wire bytes >=3.5x vs exact at every TP degree
    (Flash Communication analog; int8 codes + bf16 scales vs fp32 ring
    all-reduce gives ~3.9x);
  * drop and quant COMPOSE: SPD50+quant8 beats either alone;
  * the OVERLAP backend's schedule hides >= 50% of modeled kept-sync
    time at every TP degree, for the headline quant8 policy and in
    aggregate across policies, under the default LatencyModel (per-cell
    hidden/exposed split reported for every policy — launch-bound cells
    like quant4's 4 kB/layer hops at tp=2 honestly hide less).
"""
import jax.numpy as jnp
import numpy as np

from benchmarks._common import (HW, Timer, emit_json, ledger_time,
                                ledger_wire_bytes)
from repro.config.base import CommPolicy, SPDPlanConfig, replace
from repro.configs import get_config
from repro.core import model as M, simtp
from repro.parallel.collectives import (LatencyModel, collective_ledger,
                                        overlap_region)

TPS = (2, 4, 8)


def transfer_ledger(cfg, plan, tp, b=1, s=128, latency=None, overlap=False):
    """Ledger capture for one batch-1 seq-128 forward (paper Fig 2
    input).  Returns the raw CommEntry list; callers price it with the
    _common ring models or `latency.summarize`.  `latency=` annotates
    every entry with its modeled est_us; `overlap=True` traces inside an
    `overlap_region` — the overlap backend's ledger seam — so kept
    quantized syncs decompose into chunked ring steps."""
    import jax
    from contextlib import nullcontext
    params = M.init_model(jax.random.PRNGKey(0), cfg)
    split = simtp.prepare_params(params, cfg, plan, tp)
    toks = jnp.zeros((b, s), jnp.int32)
    region = (overlap_region((latency or LatencyModel()).ring_chunks)
              if overlap else nullcontext())
    with collective_ledger(latency=latency, tp=tp) as led:
        with region:
            fn = simtp.make_logits_fn(cfg, plan, tp, q_chunk=128)
            fn(split, toks, None)
    return led


def _policy_plan(cfg, name):
    """Named policy -> plan(+comm).  drop* use a 100%/50% first-k SPD
    plan; quant* attach a uniform CommPolicy to the kept syncs."""
    n = cfg.n_layers
    if name == "exact":
        return SPDPlanConfig.none(n)
    if name == "quant8":
        return SPDPlanConfig.none(n).with_comm(CommPolicy.uniform(n, "quant8"))
    if name == "quant4":
        return SPDPlanConfig.none(n).with_comm(CommPolicy.uniform(n, "quant4"))
    if name == "drop":
        return SPDPlanConfig.full(n)
    if name == "drop50+quant8":
        return SPDPlanConfig.first_k(n, n // 2).with_comm(
            CommPolicy.uniform(n, "quant8"))
    raise ValueError(name)


POLICIES = ("exact", "quant8", "quant4", "drop", "drop50+quant8")


def run(csv):
    # reduced llama2 stands in for LLaMA2-70B; the BYTES RATIO vs policy
    # is scale-free (both attention and MLP syncs move B*S*d each)
    cfg = replace(get_config("llama2-7b", reduced=True), dtype="float32")
    rows = []

    # ---- paper Fig 2: wire bytes vs SPD% (exact syncs) ----
    tp = 8
    base_wire = None
    for pct in (0, 25, 50, 75, 100):
        k = int(round(cfg.n_layers * pct / 100))
        plan = SPDPlanConfig.first_k(cfg.n_layers, k)
        t = Timer()
        led = transfer_ledger(cfg, plan, tp)
        us = t.us()
        wire = ledger_wire_bytes(led, tp)
        if base_wire is None:
            base_wire = wire
        t_hbw = ledger_time(led, tp, HW["hbw_eff"]) * 1e6
        t_lbw = ledger_time(led, tp, HW["lbw_eff"]) * 1e6
        red = 100 * (1 - wire / base_wire)
        csv(f"transfer/spd{pct}", us,
            f"wire_bytes={wire:.0f} reduction={red:.1f}% "
            f"t_hbw_us={t_hbw:.1f} t_lbw_us={t_lbw:.1f}")
        rows.append({"kind": "spd", "spd_pct": pct, "tp": tp,
                     "wire_bytes": wire, "red_pct": red,
                     "t_hbw_us": t_hbw, "t_lbw_us": t_lbw})
    # paper's headline: 100% SPD removes >=46% of transfer in all settings
    assert rows[-1]["red_pct"] >= 40.0, rows[-1]

    # ---- comm-policy curves: drop vs quant vs exact at TP in {2,4,8} ----
    for tp in TPS:
        wires, ar_wire = {}, {}
        for pol in POLICIES:
            plan = _policy_plan(cfg, pol)
            t = Timer()
            led = transfer_ledger(cfg, plan, tp)
            us = t.us()
            wire = ledger_wire_bytes(led, tp)
            wires[pol] = wire
            ar_wire[pol] = ledger_wire_bytes(
                [e for e in led if e.op == "all-reduce"], tp)
            t_hbw = ledger_time(led, tp, HW["hbw_eff"]) * 1e6
            t_lbw = ledger_time(led, tp, HW["lbw_eff"]) * 1e6
            speedup = wires["exact"] / max(wire, 1.0)
            csv(f"transfer/tp{tp}/{pol}", us,
                f"wire_bytes={wire:.0f} vs_exact={speedup:.2f}x "
                f"t_hbw_us={t_hbw:.1f} t_lbw_us={t_lbw:.1f}")
            rows.append({"kind": "policy", "policy": pol, "tp": tp,
                         "wire_bytes": wire, "vs_exact": speedup,
                         "t_hbw_us": t_hbw, "t_lbw_us": t_lbw})
        # per-BLOCK-sync reduction: the ARs still present under quant8 are
        # exactly the pinned-exact ones (embedding lookup), so the block
        # syncs moved (exact_AR - quant_AR) bytes before and (RS + AG =
        # total - AR) bytes after.  int8 codes + bf16 scales vs an fp32
        # ring all-reduce => ~3.9x, asserted >= 3.5x at every TP degree.
        block_exact = ar_wire["exact"] - ar_wire["quant8"]
        block_quant = wires["quant8"] - ar_wire["quant8"]
        red8 = block_exact / max(block_quant, 1.0)
        csv(f"transfer/tp{tp}/quant8_block_syncs", 0.0,
            f"block_sync_reduction={red8:.2f}x")
        rows.append({"kind": "block_sync", "tp": tp, "quant8_vs_exact": red8})
        assert red8 >= 3.5, (tp, red8, wires)
        assert wires["quant4"] < wires["quant8"], (tp, wires)
        # drop and quant compose: SPD50+quant8 beats either alone
        assert wires["drop50+quant8"] < min(wires["quant8"], wires["drop"]), \
            (tp, wires)

    # ---- modeled hidden vs exposed comm time (the overlap backend) ----
    # Every entry is priced by the default LatencyModel; the serial
    # reading (shard backend) exposes everything, the overlap reading
    # (overlap backend's chunked-ring trace) hides the double-buffered
    # fraction.  Gates: quant8 (headline) and the per-TP aggregate hide
    # >= 50% of kept-sync time; per-cell fractions are reported for all.
    lat = LatencyModel()
    for tp in TPS:
        agg_hidden = agg_kept = 0.0
        for pol in POLICIES:
            plan = _policy_plan(cfg, pol)
            t = Timer()
            led_s = transfer_ledger(cfg, plan, tp, latency=lat)
            serial = lat.summarize(led_s, overlap=False)
            led_o = transfer_ledger(cfg, plan, tp, latency=lat,
                                    overlap=True)
            ov = lat.summarize(led_o, overlap=True)
            us = t.us()
            frac = (ov["hidden_us"] / ov["kept_sync_us"]
                    if ov["kept_sync_us"] else 0.0)
            agg_hidden += ov["hidden_us"]
            agg_kept += ov["kept_sync_us"]
            csv(f"transfer/tp{tp}/{pol}/latency", us,
                f"serial_us={serial['total_us']:.2f} "
                f"hidden_us={ov['hidden_us']:.2f} "
                f"exposed_us={ov['exposed_us']:.2f} "
                f"hidden_frac_of_kept={frac:.2f}")
            rows.append({"kind": "latency", "policy": pol, "tp": tp,
                         "serial_us": serial["total_us"],
                         "total_us": ov["total_us"],
                         "hidden_us": ov["hidden_us"],
                         "exposed_us": ov["exposed_us"],
                         "kept_sync_us": ov["kept_sync_us"],
                         "hidden_frac_of_kept": frac})
            # hidden + exposed account for every modeled microsecond
            assert abs(ov["hidden_us"] + ov["exposed_us"]
                       - ov["total_us"]) < 1e-6, (tp, pol, ov)
            if pol == "quant8":
                assert frac >= 0.5, (tp, pol, ov)
        agg = agg_hidden / max(agg_kept, 1e-9)
        csv(f"transfer/tp{tp}/overlap_aggregate", 0.0,
            f"hidden_frac_of_kept={agg:.2f}")
        rows.append({"kind": "latency_aggregate", "tp": tp,
                     "hidden_frac_of_kept": agg})
        assert agg >= 0.5, (tp, agg_hidden, agg_kept)
    emit_json("transfer", {"arch": cfg.name, "tps": list(TPS),
                           "policies": list(POLICIES),
                           "latency": {"link_bytes_per_s": lat.link_bytes_per_s,
                                       "launch_us": lat.launch_us,
                                       "ring_chunks": lat.ring_chunks}},
              rows)
    return rows
