"""Fig 7 (bottom) analog: normalized TTFT speedup vs SPD% at pod scale.

The paper measures wall-clock time-to-first-token speedup on A100 nodes;
we derive the same curve from re-lowered dry-run cells of the paper's
70B-class setting (qwen2-72b × prefill_32k × 16×16 v5e):
step ≈ max(compute, memory, collective) with the collective term from the
exact trace-ledger payloads.  The HBW/LBW analog: ICI 50 GB/s vs a
10 GB/s degraded-interconnect model applied to the SAME payloads.

A companion MEASURED section drives the `repro.api` facade end-to-end
(the same reduced model served at SPD 0% vs 70% through `LLM.generate`)
so the curve has a wall-clock anchor on real serving steps, not only
the analytic roofline.
"""
import glob
import json
import os

from benchmarks._common import emit_json
from benchmarks.roofline import analyze, collective_term


def _measured_rows(csv):
    """Wall-clock tokens/sec through the facade, SPD 0% vs 70% (sim
    engine, reduced model).  Informational — CPU-sim timings carry no
    interconnect, so no speedup assertion is made here."""
    import numpy as np

    from benchmarks._common import Timer, train_reduced
    from repro.api import LLM, SamplingParams

    cfg, canonical = train_reduced(steps=0)
    rows, base = [], None
    for spd in (0.0, 0.7):
        llm = LLM.load(cfg, tp=2, engine="sim", spd=spd, params=canonical,
                       cache_len=64, max_batch=4, q_chunk=64)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size,
                                int(rng.integers(6, 16))).astype(np.int32)
                   for _ in range(8)]
        sp = SamplingParams(max_new=8)
        llm.generate(prompts, sp)            # compile/warm every shape
        t = Timer()
        outs = llm.generate(prompts, sp)
        us = t.us()
        toks = sum(len(o.token_ids) for o in outs)
        tps = toks / (us / 1e6)
        base = base or tps
        rows.append({"spd": spd, "measured_tok_per_s": tps,
                     "measured_speedup": tps / base})
        csv(f"speedup/measured/spd{int(spd*100)}", us / toks,
            f"tok/s={tps:.1f} speedup={tps / base:.3f}")
    return rows


def run(csv):
    cells = {}
    for p in glob.glob("results/perf/A_*.json"):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("sync_q8") or rec.get("w_int8"):
            continue
        cells[rec["spd"]] = rec
    if 0.0 not in cells:
        csv("speedup/skipped", 0, "run the §Perf dry-run cells first "
            "(results/perf/A_*.json)")
        rows = _measured_rows(csv)
        emit_json("speedup", {"source": "measured-only", "engine": "sim"},
                  rows)
        return rows
    rows = _measured_rows(csv)
    base = {}
    for bw_name, bw in (("hbw", 50e9), ("lbw", 10e9)):
        import benchmarks.roofline as R
        old = R.HW["ici_bw"]
        R.HW["ici_bw"] = bw
        try:
            t0 = None
            for spd in sorted(cells):
                r = analyze(cells[spd])
                step = r["step_time_est"]
                if spd == 0.0:
                    t0 = step
                speedup = t0 / step
                rows.append({"spd": spd, "bw": bw_name,
                             "step_ms": step * 1e3, "speedup": speedup})
                csv(f"speedup/{bw_name}/spd{int(spd*100)}", step * 1e6,
                    f"speedup={speedup:.3f} dom={r['dominant']}")
        finally:
            R.HW["ici_bw"] = old
    # paper claim: >=10% speedup at SPD >= 70% in both bandwidth regimes
    for bw_name in ("hbw", "lbw"):
        hi = [r for r in rows
              if r.get("bw") == bw_name and r["spd"] >= 0.7]
        assert hi and max(r["speedup"] for r in hi) >= 1.10, (bw_name, rows)
    emit_json("speedup", {"source": "results/perf/A_*.json",
                          "engine": "sim"}, rows)
    return rows
