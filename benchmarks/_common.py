"""Shared benchmark utilities: a cached briefly-trained reduced model,
the TPU-v5e analytic communication-time model, and the machine-readable
per-bench JSON emitter (`emit_json`) that tracks the perf trajectory
across PRs."""
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.config.base import SPDPlanConfig, replace
from repro.configs import get_config
from repro.core import model as M, simtp
from repro.data.synthetic import calibration_batches, cloze_suite
from repro.optim.adamw import adamw_init, adamw_update

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                         "bench_models")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _git_commit() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def emit_json(bench: str, config: dict, metrics, root: str = None) -> str:
    """Write `BENCH_<bench>.json` at the repo root (schema: {bench,
    config, metrics, commit}) so every benchmark run leaves a
    machine-readable artifact the perf trajectory can be tracked from
    across PRs.  `metrics` is whatever the bench's `run()` returns
    (typically its rows list); `config` the knobs that shaped the run.
    Every config block records the RESOLVED parallel backend (registry
    name + class) behind the run's `engine`.  Unstated engine defaults
    to "sim" — correct for every bench here, which all run either the
    sim Engine or the simtp vmap math (the same backend regime); a
    bench with no model execution at all can pass `engine=None` to
    record `backend: null`.  Returns the path written."""
    from repro.parallel.backend import resolved_backend_name
    config = dict(config)
    engine = config.get("engine", "sim")
    config.setdefault(
        "backend", resolved_backend_name(engine) if engine else None)
    # every config block records its replica count: 1 for single-engine
    # benches, the swept list for the cluster bench — so the perf
    # trajectory can tell DP-over-TP runs from plain ones at a glance
    config.setdefault("replicas", 1)
    path = os.path.join(root or REPO_ROOT, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "config": config, "metrics": metrics,
                   "commit": _git_commit()}, f, indent=1, default=str)
    return path

# hardware constants (TPU v5e targets; see EXPERIMENTS.md §Roofline)
HW = {
    "peak_flops_bf16": 197e12,
    "hbm_gbps": 819e9,
    "ici_link_gbps": 50e9,      # HBW analog (intra-pod ICI)
    "dcn_gbps": 1.5e9,          # LBW analog (cross-pod DCN per chip)
    "hbw_eff": 50e9,            # paper HBW=300GB/s NVLink -> ICI 50GB/s
    "lbw_eff": 10e9,            # paper LBW=10GB/s -> same constant
}


def ring_all_reduce_time(payload_bytes: float, n: int, bw: float) -> float:
    """Ring all-reduce wall time: 2 (n-1)/n * payload / bw."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * payload_bytes / bw


def collective_wire_bytes(op: str, payload_bytes: float, n: int) -> float:
    """Bytes a single device puts on the wire for one logical collective,
    under the ring algorithms, given the LEDGER's payload convention
    (parallel/collectives.py): all-reduce and reduce-scatter log the full
    per-device operand; all-gather logs the per-device SLICE input.
    Thin alias of `collectives.ring_wire_bytes` — the ledger's own
    latency model and the benches price bytes identically."""
    from repro.parallel.collectives import ring_wire_bytes
    return ring_wire_bytes(op, payload_bytes, n)


def ledger_wire_bytes(ledger, n: int) -> float:
    """Total per-device ring-wire bytes for a trace-time ledger capture —
    THE analytic transfer quantity (reads every op the ledger recorded,
    so quantized syncs, which log as reduce-scatter + all-gather pairs —
    or chunked collective-permute ring steps under the overlap backend —
    are accounted at their true low-bit payloads instead of being
    re-derived from activation shapes)."""
    return sum(collective_wire_bytes(e.op, e.nbytes, n) for e in ledger)


def ledger_time(ledger, n: int, bw: float) -> float:
    """Ring wall time of every ledger collective at link bandwidth bw."""
    return ledger_wire_bytes(ledger, n) / bw


def train_reduced(arch="smollm-360m", steps=80, tp=2, seed=0, seq=48,
                  batch=8, lr=3e-3):
    """Train (or load cached) a reduced model on the synthetic corpus."""
    cfg = replace(get_config(arch, reduced=True), dtype="float32")
    ckpt_dir = os.path.join(BENCH_DIR, f"{arch}_s{steps}_v2")
    plan = SPDPlanConfig.none(cfg.n_layers)
    params0 = M.init_model(jax.random.PRNGKey(seed), cfg)
    res = load_checkpoint(ckpt_dir, tree_like=params0)
    if res is not None:
        return cfg, res[1]
    split = simtp.prepare_params(params0, cfg, plan, tp)
    gfn = simtp.make_grad_fn(cfg, plan, tp, q_chunk=64)
    opt = adamw_init(split)
    from repro.data.synthetic import make_batch_iterator
    it = make_batch_iterator(cfg.vocab_size, batch, seq, seed=seed)
    for i in range(steps):
        b = next(it)
        bb = {k: jnp.asarray(v) for k, v in b.items()
              if not k.startswith("_")}
        _, g = gfn(split, bb)
        split, opt = adamw_update(g, opt, split, lr=lr)
    merged = simtp.merge_stacked(split, cfg, plan, tp)
    canonical = M.unstack_segments(merged, cfg, plan)
    save_checkpoint(ckpt_dir, steps, canonical)
    return cfg, canonical


def quality(cfg, padded_or_canonical, plan, tp, calib, suite=None,
            q_chunk=64, already_padded=False):
    """(ppl, cloze accuracy) on the synthetic eval suites."""
    if already_padded:
        from repro.core.spd import prepare_deployment
        split = prepare_deployment(cfg, padded_or_canonical, plan, tp)
    else:
        split = simtp.prepare_params(padded_or_canonical, cfg, plan, tp)
    lf = simtp.make_loss_fn(cfg, plan, tp, q_chunk=q_chunk)
    ppl = simtp.eval_ppl(lf, split, calib)
    acc = None
    if suite is not None:
        lgf = simtp.make_logits_fn(cfg, plan, tp, q_chunk=q_chunk)
        acc = simtp.eval_cloze(lgf, split, suite)
    return ppl, acc


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def us(self, calls=1):
        return (time.perf_counter() - self.t0) * 1e6 / max(calls, 1)
