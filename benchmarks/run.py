"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``name,us_per_call,derived`` CSV lines.

  bench_transfer     Fig 2   data-transfer time vs SPD% (HBW/LBW model)
  bench_sensitivity  Fig 6   block sensitivity profile + ISB fraction
  bench_accuracy     Fig 7/8 quality vs SPD budget x strategy
  bench_ablation     Table 1 residual-design ablations (1a no-bias, 1b bias)
  roofline           --      SRoofline terms from the dry-run artifacts
  bench_serving      --      dense vs paged-KV serving throughput
  bench_spec         --      self-speculative decoding: acceptance,
                             tokens/step, draft wire savings
  bench_cluster      --      DP-over-TP cluster serving: tokens/sec
                             scaling at 1/2/4 replicas, router policies

Every bench_* module also writes a machine-readable ``BENCH_<name>.json``
at the repo root ({bench, config, metrics, commit} — see
``benchmarks/_common.emit_json``) so the perf trajectory is tracked
across PRs.
"""
import argparse
import json
import os
import sys
import traceback


def _csv(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--out", default="results/bench")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from benchmarks import (bench_ablation, bench_accuracy, bench_cluster,
                            bench_sensitivity, bench_serving, bench_spec,
                            bench_speedup, bench_transfer, roofline)
    suites = {
        "transfer": bench_transfer.run,
        "sensitivity": bench_sensitivity.run,
        "accuracy": bench_accuracy.run,
        "ablation": bench_ablation.run,
        "speedup": bench_speedup.run,
        "roofline": roofline.run,
        "serving": bench_serving.run,
        "spec": bench_spec.run,
        "cluster": bench_cluster.run,
    }
    failures = 0
    for name, fn in suites.items():
        if args.only and name not in args.only:
            continue
        try:
            rows = fn(_csv)
            with open(os.path.join(args.out, f"{name}.json"), "w") as f:
                json.dump(rows, f, indent=1, default=str)
        except Exception:
            failures += 1
            print(f"{name},0,FAILED")
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
