"""Fig 6 analog: block-wise sync-sensitivity profile + ISB/SB/ESB split.

Paper claim validated qualitatively: a large fraction of blocks is
in-sensitive (droppable zero-shot with ~no ppl change), sensitivity is
strongly non-uniform, and the profile grows more tolerant with size
(shown here across two reduced model sizes)."""
import numpy as np

from benchmarks._common import Timer, emit_json, train_reduced
from repro.config.base import SPDPlanConfig
from repro.core import sensitivity as S
from repro.core import simtp
from repro.data.synthetic import calibration_batches


def run(csv):
    rows = []
    for arch, steps in (("smollm-360m", 400), ("qwen3-1.7b", 400)):
        cfg, canonical = train_reduced(arch, steps=steps, seq=64)
        tp = 2
        plan = SPDPlanConfig.none(cfg.n_layers)
        split = simtp.prepare_params(canonical, cfg, plan, tp)
        calib = calibration_batches(cfg.vocab_size, 16, 64, batch=8)[:2]
        t = Timer()
        res = S.measure_sensitivity(cfg, split, calib, tp, q_chunk=64)
        us = t.us(cfg.n_layers + 1)
        tau1 = max(0.02 * res.ppl_suffix[-1], 1e-3)
        cats = S.classify(res.sensitivity, tau1=tau1, tau2=50 * tau1)
        frac_isb = cats.count(S.ISB) / len(cats)
        csv(f"sensitivity/{arch}", us,
            f"isb_frac={frac_isb:.2f} sens={np.array2string(res.sensitivity, precision=3)}")
        rows.append({"arch": arch, "sens": res.sensitivity.tolist(),
                     "ppl_suffix": res.ppl_suffix.tolist(),
                     "cats": cats, "isb_frac": frac_isb})
    emit_json("sensitivity",
              {"archs": ["smollm-360m", "qwen3-1.7b"], "steps": 400,
               "tp": 2}, rows)
    return rows
